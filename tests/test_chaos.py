"""Chaos-plane tests: the no-chaos byte-identity contract, campaign
determinism and engine parity, fault↔recovery pairing, the
degradation-ladder seams (node agents, serving lanes, the campaign's
FaultInjector protocol), snapshot round-trips, the verification harness
end to end, and the CLI's actionable failure modes (broken --resume,
--verify-manifest without the signing key)."""
import dataclasses
import json
import types

import numpy as np
import pytest

from repro import cli
from repro.chaos import (CHAOS_SCHEMA, ChaosCampaign, ChaosConfig,
                         ScriptedInjector)
from repro.cluster.agents import AgentConfig, NodeAgentFleet
from repro.cluster.control import check_schema, run_scenario
from repro.cluster.scenario import scenario_by_name
from repro.serving_plane import ArrivalProcess, resolve_admission
from repro.serving_plane.plane import _Lane


def _storm(seed=7, devices=12, hours=1.0, **kw):
    """chaos-storm shrunk to test size, with the injection window clamped
    the same way the harness clamps it (every episode closes in time)."""
    sc = scenario_by_name("chaos-storm").with_overrides(
        seed=seed, n_devices=devices, hours=hours, **kw)
    end_s = max(0.0, sc.horizon_seconds() - 1200.0)
    return dataclasses.replace(
        sc, chaos=dataclasses.replace(sc.chaos, end_s=end_s))


@pytest.fixture(scope="module")
def storm_report():
    return run_scenario(_storm())


# ------------------------------------------------------- byte-identity
def test_zero_rate_campaign_keeps_trajectory_byte_identical():
    """The seams' contract: a wired-in campaign whose every rate is 0.0
    never perturbs the trajectory — only the scenario echo and the
    "resilience" section may differ from a chaos=None run."""
    sc = scenario_by_name("chaos-storm").with_overrides(
        seed=3, n_devices=8, hours=0.5)
    plain = run_scenario(dataclasses.replace(sc, chaos=None))
    zeroed = run_scenario(dataclasses.replace(sc, chaos=ChaosConfig()))
    assert set(plain) == set(zeroed)
    for key in plain:
        if key in ("scenario", "resilience"):
            continue
        assert (json.dumps(plain[key], sort_keys=True)
                == json.dumps(zeroed[key], sort_keys=True)), key
    assert plain["resilience"] is None
    assert zeroed["resilience"]["injected"] == 0
    assert zeroed["resilience"]["open_end"] == 0


def test_same_seed_chaos_report_byte_identical(storm_report):
    again = run_scenario(_storm())
    assert (json.dumps(again, sort_keys=True)
            == json.dumps(storm_report, sort_keys=True))


def test_engine_parity_under_chaos(storm_report):
    xla = run_scenario(_storm(engine="xla"))
    assert (json.dumps(xla, sort_keys=True)
            == json.dumps(storm_report, sort_keys=True))


# ------------------------------------------- pairing + report contract
def test_every_injected_fault_pairs_with_a_recovery(storm_report):
    res = storm_report["resilience"]
    assert res["schema"] == CHAOS_SCHEMA
    assert res["injected"] > 0
    assert res["unmatched"] == 0 and res["unmatched_by_kind"] == {}
    assert res["open_end"] == 0
    assert res["recovered"] == res["injected"]
    assert storm_report["schema"].endswith("/v5")
    assert check_schema(storm_report) == []


def test_ladder_counters_consistent_with_fault_counts(storm_report):
    res = storm_report["resilience"]
    lad, inj = res["ladder"], res["injected_by_kind"]
    assert lad["agent_restarts"] == inj.get("agent_crash", 0)
    assert lad["matcher_fallback_rounds"] == inj.get("matcher_budget", 0)
    if inj.get("wal_io"):
        # every consumed IO fault was absorbed by at least one retry
        assert lad["store_faults"] > 0
        assert lad["store_retries"] >= lad["store_faults"]
    if inj.get("predictor_outage"):
        assert lad["predictor_fallback_rounds"] > 0


# --------------------------------------------- campaign protocol units
class _CampSim:
    def __init__(self, n):
        self.cfg = types.SimpleNamespace(n_devices=n)


def _campaign(**cfg_kw):
    return ChaosCampaign(ChaosConfig(**cfg_kw), _CampSim(4), seed=1)


def test_quiet_campaign_every_seam_returns_neutral():
    camp = _campaign()
    camp.inject(5.0, 5.0)
    assert camp.agent_outage(5.0) is None
    assert camp.heartbeat_skew(5.0) is None
    assert camp.store_fault("append") is False
    assert camp.predictor_down(5.0) is False
    assert camp.matcher_exhausted(5.0) is False
    assert camp.serving_burst_mult(5.0) == 1.0
    assert camp.brownout_frac(5.0) == 0.0
    assert camp.summary()["injected"] == 0


def test_wal_burst_consumed_then_drained_as_one_pair():
    camp = _campaign(wal_fault_rate_per_hour=1e9, wal_fault_burst=2)
    camp.inject(5.0, 5.0)                      # arms the burst (p >> 1)
    assert camp.store_fault("append") and camp.store_fault("flush")
    assert not camp.store_fault("append")      # burst exhausted
    camp.note_io_recovered("append", 2)
    camp.inject(10.0, 5.0)                     # drains the deferred pair
    s = camp.summary()
    assert s["injected_by_kind"]["wal_io"] == 1
    assert s["recovered_by_kind"]["wal_io"] == 1
    assert s["ladder"]["store_faults"] == 2
    assert s["ladder"]["store_retries"] == 2


def test_matcher_budget_exhaustion_is_one_shot():
    camp = _campaign(matcher_budget_rate_per_hour=1e9)
    camp.inject(5.0, 5.0)
    assert camp.matcher_exhausted(5.0)
    camp.note_matcher_fallback(5.0, 3, 7)      # consumed by this round
    assert not camp.matcher_exhausted(5.0)
    s = camp.summary()
    assert s["injected_by_kind"]["matcher_budget"] == 1
    assert s["recovered_by_kind"]["matcher_budget"] == 1
    assert s["ladder"]["matcher_fallback_rounds"] == 1


def test_brownout_tiers_escalate_over_the_burst():
    camp = _campaign(serving_burst_rate_per_hour=1e9,
                     serving_burst_s=300.0, brownout_shed_frac=0.1)
    camp.inject(5.0, 5.0)                      # burst opens at t=5
    assert camp.serving_burst_mult(6.0) == pytest.approx(2.5)
    assert camp.brownout_frac(6.0) == pytest.approx(0.1)     # tier 1
    assert camp.brownout_frac(290.0) == pytest.approx(0.3)   # tier 3
    assert camp.brownout_frac(400.0) == 0.0    # burst over
    assert camp.serving_burst_mult(400.0) == 1.0


def test_campaign_capture_restore_resumes_identically():
    kw = dict(agent_crash_rate_per_hour=50.0, clock_skew_rate_per_hour=50.0,
              wal_fault_rate_per_hour=50.0, agent_restart_s=30.0,
              clock_skew_len_s=30.0)
    camp, twin = _campaign(**kw), _campaign(**kw)
    for i in range(20):
        camp.inject(5.0 * (i + 1), 5.0)
    twin.restore(camp.capture())
    for i in range(20, 60):
        camp.inject(5.0 * (i + 1), 5.0)
        twin.inject(5.0 * (i + 1), 5.0)
    assert camp.summary() == twin.summary()


# --------------------------------------------------- agent-fleet seams
class _AgentSim:
    def __init__(self, n):
        self.state = types.SimpleNamespace(
            sm_share=np.full(n, 0.5), has_job=np.zeros(n, bool))
        self.monitor = types.SimpleNamespace(state=np.zeros(n, np.int8))


def test_agent_outage_turns_stale_after_timeout():
    n = 4
    fleet = NodeAgentFleet(n, AgentConfig(), seed=0)
    fleet.fault_injector = ScriptedInjector(
        down_mask=np.array([True, False, False, False]))
    sim = _AgentSim(n)
    mask = None
    for t in (0.0, 30.0, 60.0, 90.0, 120.0):
        mask = fleet.observe(sim, t, {})
    # 3 heartbeats (90 s) missed -> the crashed agent's device is masked out
    assert mask.tolist() == [False, True, True, True]
    assert fleet.stale_episodes == 1
    fleet.fault_injector = None                # agent restarts
    assert fleet.observe(sim, 150.0, {}).all()


def test_heartbeat_skew_makes_live_device_look_stale():
    n = 3
    fleet = NodeAgentFleet(n, AgentConfig(), seed=0)
    inj = ScriptedInjector(skew_s=120.0)
    fleet.fault_injector = inj
    sim = _AgentSim(n)
    # reports stamped 120 s in the past: past the 90 s staleness timeout
    assert not fleet.observe(sim, 0.0, {}).any()
    inj.skew_s = 0.0                           # skew episode ends
    mask = fleet.observe(sim, 30.0, {})
    assert mask.all()
    # telemetry from the skewed beat still landed (the agent was live)
    assert fleet.seen["sm_share"][0] == pytest.approx(0.5)


# -------------------------------------------------- serving-lane seams
def _lane(times):
    return _Lane("svc", np.array([0]), np.array([1.0]),
                 ArrivalProcess.trace_replay(np.asarray(times, float)),
                 resolve_admission("none"), slo_ms=1000.0,
                 base_latency_ms=50.0, qps_capacity=10.0,
                 size_rng=np.random.default_rng(0), sigma=0.0, sub=1)


def test_brownout_sheds_oldest_cohorts_first():
    lane = _lane(np.concatenate([np.full(4, 0.1), np.full(4, 1.1)]))
    lane.step(0.0, 1.0, 0.0, 50.0)                      # 4 queued
    lane.step(1.0, 1.0, 0.0, 50.0, brownout_frac=0.5)   # 8 queued, shed 4
    assert lane.brownout_shed == 4 and lane.shed == 4
    assert [c[0] for c in lane.queue] == [1.5]          # oldest cohort gone
    assert sum(c[1] for c in lane.queue) == 4


def test_overload_burst_multiplies_demand_after_the_draw():
    a, b = _lane(np.full(4, 0.2)), _lane(np.full(4, 0.2))
    a.step(0.0, 1.0, 0.0, 50.0)
    b.step(0.0, 1.0, 0.0, 50.0, demand_mult=3.0)
    assert a.arrived == 4 and b.arrived == 12
    assert sum(c[1] for c in b.queue) == 12


# ------------------------------------------------- harness end to end
def test_chaos_verification_harness_all_invariants_hold(tmp_path):
    from repro.chaos.harness import VERIFY_SCHEMA, run_chaos_verification
    doc = run_chaos_verification(
        "chaos-storm", workdir=str(tmp_path), seed=7, devices=12,
        hours=1.0, snapshot_every_s=300.0)
    assert doc["schema"] == VERIFY_SCHEMA
    assert doc["ok"], doc["invariants"]
    names = {i["name"] for i in doc["invariants"]}
    assert {"faults-injected", "fault-recovery-pairing", "zero-event-loss",
            "store-retry-ladder", "slo-degradation-budget",
            "recovery-byte-identity",
            "snapshot-skip-to-next-good"} <= names
    assert doc["slo"]["baseline_attainment"] is not None


# ------------------------------------------------------- CLI contracts
def test_cli_chaos_rejects_scenario_without_chaos(tmp_path, capsys):
    rc = cli.chaos_main(["--scenario", "smoke", "--workdir", str(tmp_path)])
    assert rc == 2
    assert "no chaos config" in capsys.readouterr().err


def test_cli_resume_missing_rundir_is_actionable(tmp_path, capsys):
    rc = cli.sim_main(["--resume", str(tmp_path / "nope")])
    assert rc == 2
    err = capsys.readouterr().err
    assert "run.json" in err and "Traceback" not in err


def test_cli_resume_garbled_pickle_is_actionable(tmp_path, capsys):
    """A scenario.pkl whose bytes were corrupted after signing (manifest
    re-signed, so the hash check passes but unpickling fails) exits 2
    with an actionable message, never a traceback."""
    from repro.durability.manifest import (file_sha256, sign_manifest,
                                           write_manifest)
    from repro.durability.runner import DurableRun
    sc = scenario_by_name("smoke").with_overrides(n_devices=4, hours=0.25)
    rundir = tmp_path / "run"
    run = DurableRun.create(sc, str(rundir))
    run.store.close()
    (rundir / "scenario.pkl").write_bytes(
        b"\x80\x05 this is not a scenario pickle")
    manifest = json.loads((rundir / "manifest.json").read_text())
    sha, size = file_sha256(str(rundir / "scenario.pkl"))
    manifest["artifacts"]["scenario.pkl"] = {"sha256": sha, "bytes": size}
    manifest["signature"] = sign_manifest(manifest)
    write_manifest(str(rundir / "manifest.json"), manifest)
    rc = cli.sim_main(["--resume", str(rundir)])
    assert rc == 2
    err = capsys.readouterr().err
    assert "damaged" in err and "Traceback" not in err


def test_cli_verify_manifest_hints_at_unset_key(tmp_path, capsys,
                                                monkeypatch):
    from repro.durability.manifest import KEY_ENV
    from repro.durability.runner import DurableRun
    monkeypatch.setenv(KEY_ENV, "a-production-signing-key")
    sc = scenario_by_name("smoke").with_overrides(n_devices=4, hours=0.25)
    run = DurableRun.create(sc, str(tmp_path / "run"))
    run.store.close()
    monkeypatch.delenv(KEY_ENV)
    rc = cli.sim_main(["--verify-manifest",
                       str(tmp_path / "run" / "manifest.json")])
    assert rc == 1
    err = capsys.readouterr().err
    assert KEY_ENV in err and "not set" in err
