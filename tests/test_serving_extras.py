"""KV-cache quantization accuracy + autoscaler behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.kernels import ref
from repro.serving.kv_quant import (decode_attention_quantized, kv_dequantize,
                                    kv_quantize, quantized_cache_bytes)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(4, 64), st.integers(1, 4),
       st.integers(0, 100))
def test_kv_quant_roundtrip_error(B, S, H, seed):
    kv = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, 16)) * 3.0
    q, scale = kv_quantize(kv)
    back = kv_dequantize(q, scale)
    err = float(jnp.abs(back - kv).max())
    assert err <= float(jnp.abs(kv).max()) / 127.0 + 1e-6   # <= 1 quantum


def test_quantized_decode_attention_close_to_fp():
    key = jax.random.PRNGKey(0)
    B, S, H, Hk, d = 2, 128, 8, 2, 64
    q = jax.random.normal(key, (B, 1, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, d))
    kq, ks = kv_quantize(k)
    vq, vs = kv_quantize(v)
    out = decode_attention_quantized(q, kq, ks, vq, vs, kv_len=100)
    want = ref.decode_attention_reference(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-2, rtol=2e-2)


def test_quantized_cache_bytes_halve_bf16():
    full_bf16 = 2 * 128 * 32768 * 8 * 128 * 2
    quant = quantized_cache_bytes(128, 32768, 8, 128) * 2
    assert quant < full_bf16 * 0.55


def test_autoscaler_scales_up_on_load():
    a = Autoscaler(AutoscalerConfig(cooldown_s=0.0), replicas=2,
                   qps_capacity_per_replica=100.0)
    d = a.observe(total_qps=200.0, now=0.0)      # load 1.0 > 0.8
    assert d is not None and d.delta > 0
    # sized to target: 200 / (100*0.6) = 3.34 -> 4
    assert a.replicas == 4


def test_autoscaler_scale_down_needs_stability_and_respects_min():
    cfg = AutoscalerConfig(cooldown_s=0.0, scale_down_stability_s=100.0,
                           min_replicas=1)
    a = Autoscaler(cfg, replicas=8, qps_capacity_per_replica=100.0)
    assert a.observe(total_qps=50.0, now=0.0) is None      # starts the clock
    assert a.observe(total_qps=50.0, now=50.0) is None     # not stable yet
    d = a.observe(total_qps=50.0, now=150.0)
    assert d is not None and d.delta < 0 and a.replicas == 1


def test_autoscaler_cooldown():
    a = Autoscaler(AutoscalerConfig(cooldown_s=300.0), replicas=1,
                   qps_capacity_per_replica=100.0)
    assert a.observe(900.0, now=0.0).replicas > 1
    assert a.observe(9000.0, now=10.0) is None             # in cooldown
    assert a.observe(9000.0, now=400.0) is not None
