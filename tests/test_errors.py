"""Mixed error handling: taxonomy, graceful exit (real signals), policy."""
import os
import signal

import numpy as np
import pytest

from repro.core.errors import (ERROR_MIX, Action, ErrorKind, GracefulExit,
                               MixedErrorHandler, sample_error)
from repro.core.protection import KernelThrottle


def test_error_mix_matches_paper():
    sig = ERROR_MIX[ErrorKind.SIGINT] + ERROR_MIX[ErrorKind.SIGTERM]
    assert sig / sum(ERROR_MIX.values()) >= 0.985   # "99% ... SIGINT/SIGTERM"


def test_signals_graceful_never_propagate():
    h = MixedErrorHandler(graceful_enabled=True)
    for k in (ErrorKind.SIGINT, ErrorKind.SIGTERM):
        out = h.handle(k)
        assert out.action == Action.GRACEFUL_EXIT and not out.propagated


def test_without_mechanism_signals_propagate():
    h = MixedErrorHandler(graceful_enabled=False)
    assert h.handle(ErrorKind.SIGINT).propagated


def test_tail_errors_reset_context():
    h = MixedErrorHandler()
    out = h.handle(ErrorKind.XID31_PAGE_FAULT)
    assert out.action == Action.RESET_CONTEXT and not out.propagated


@pytest.mark.parametrize("detector", [True, False])
@pytest.mark.parametrize("graceful", [True, False])
@pytest.mark.parametrize("kind", list(ErrorKind))
def test_action_matrix_complete(kind, graceful, detector):
    """The full ErrorKind x (graceful, detector) policy matrix: signals go
    graceful (never propagate) only with the mechanism on; tail errors
    always reset the context and propagate only without the detector."""
    h = MixedErrorHandler(graceful_enabled=graceful,
                          detector_enabled=detector)
    out = h.handle(kind)
    if kind in MixedErrorHandler.SIGNAL_KINDS:
        want = Action.GRACEFUL_EXIT if graceful else Action.RESET_CONTEXT
        assert out.action == want
        assert out.propagated == (not graceful)
    else:
        assert out.action == Action.RESET_CONTEXT
        assert out.propagated == (not detector)
    assert h.handled == [out]


def test_propagation_rate_zero_handled_is_zero():
    assert MixedErrorHandler().propagation_rate() == 0.0


def test_propagation_rate_mixed():
    h = MixedErrorHandler(graceful_enabled=False)
    h.handle(ErrorKind.SIGINT)              # propagates without graceful
    h.handle(ErrorKind.XID31_PAGE_FAULT)    # detector absorbs it
    assert h.propagation_rate() == 0.5


def test_sample_error_distribution():
    rng = np.random.default_rng(0)
    kinds = [sample_error(rng) for _ in range(4000)]
    frac_sig = sum(k in (ErrorKind.SIGINT, ErrorKind.SIGTERM) for k in kinds) / 4000
    assert frac_sig > 0.97


def test_graceful_exit_intercepts_sigterm():
    events = []
    throttle = KernelThrottle()
    gex = GracefulExit(throttle=throttle,
                       on_checkpoint=lambda: events.append("ckpt"),
                       on_release=lambda: events.append("release"))
    with gex:
        os.kill(os.getpid(), signal.SIGTERM)
        # handler runs synchronously in the main thread
        assert gex.triggered == ErrorKind.SIGTERM
    assert events == ["ckpt", "release"]
    assert throttle.frozen                      # kernel launches frozen
    assert not throttle.should_launch(1.0)
    # handler restored afterwards
    assert signal.getsignal(signal.SIGTERM) not in (gex._handler,)
