"""Wall-clock-leak lint: deterministic artifact producers must emit the same
bytes no matter what the wall clock says.

Strategy: poison ``time.time`` / ``time.perf_counter`` / ``time.monotonic``
with deterministic fake clocks started at two wildly different bases and
advancing by a large stride per call, then produce each artifact under both
clocks and require byte equality.  Any wall-clock value that leaks into an
artifact changes the bytes and fails the test.

Audit notes (producers deliberately *outside* this lint):

* ``cli.py`` / ``benchmarks`` time wall for stderr notes and the
  BENCH_sim.json perf artifact — wall is their payload, never part of a
  deterministic artifact.
* ``launch/dryrun.py`` reports carry ``lower_s`` / ``compile_s`` by design:
  they are compile-timing artifacts, explicitly outside the byte-identity
  discipline (their own docs say so).
* ``launch/serve.py`` / ``launch/train.py`` are interactive demos, not
  artifact producers.
* ``runtime/fault_tolerance.py`` uses ``time.monotonic`` only as a default
  when no logical ``now`` is injected; the sim paths always inject.
* ``obs/phases.py`` is the one *intentional* wall-clock consumer in the
  obs plane — quarantined to stderr + BENCH_sim.json
  (``test_obs.test_profile_phases_never_lands_in_report`` pins that).
"""
import json
import time

import pytest

from repro.cluster.control import run_scenario
from repro.obs import ObsConfig

TINY = dict(n_devices=24, hours=0.5, seed=0)


def _poison_clock(monkeypatch, base: float):
    state = {"t": base}

    def fake_clock():
        state["t"] += 977.0       # big stride: any leak moves the bytes
        return state["t"]

    monkeypatch.setattr(time, "time", fake_clock)
    monkeypatch.setattr(time, "perf_counter", fake_clock)
    monkeypatch.setattr(time, "monotonic", fake_clock)


def _scenario_artifacts(tmp_path, tag):
    obs = ObsConfig(metrics_out=str(tmp_path / f"m{tag}.jsonl"),
                    trace_out=str(tmp_path / f"t{tag}.jsonl"),
                    prom_out=str(tmp_path / f"p{tag}.prom"))
    rep = run_scenario("smoke", obs=obs, **TINY)
    return (json.dumps(rep, sort_keys=True).encode(),
            (tmp_path / f"m{tag}.jsonl").read_bytes(),
            (tmp_path / f"t{tag}.jsonl").read_bytes(),
            (tmp_path / f"p{tag}.prom").read_bytes())


def test_scenario_report_and_obs_exports_ignore_wall_clock(
        tmp_path, monkeypatch):
    _poison_clock(monkeypatch, base=0.0)
    a = _scenario_artifacts(tmp_path, "a")
    _poison_clock(monkeypatch, base=4.0e9)
    b = _scenario_artifacts(tmp_path, "b")
    for name, x, y in zip(("report", "metrics", "trace", "prom"), a, b):
        assert x == y, f"wall clock leaked into {name}"


def test_profile_phases_artifacts_stay_clean_under_poisoned_clock(
        tmp_path, monkeypatch):
    # phase profiling *consumes* the poisoned clock (that's its job) but
    # must not let it reach the report or the exports
    outs = []
    for tag, base in (("a", 0.0), ("b", 7.7e8)):
        _poison_clock(monkeypatch, base=base)
        obs = ObsConfig(metrics_out=str(tmp_path / f"m{tag}.jsonl"),
                        profile_phases=True)
        rep = run_scenario("smoke", obs=obs, **TINY)
        outs.append((json.dumps(rep, sort_keys=True).encode(),
                     (tmp_path / f"m{tag}.jsonl").read_bytes()))
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_speed_matrix_artifact_ignores_wall_clock(monkeypatch):
    from repro.profiling.harness import build_speed_matrix
    blobs = []
    for base in (0.0, 3.3e9):
        _poison_clock(monkeypatch, base=base)
        blobs.append(build_speed_matrix("smoke", seed=0).to_json().encode())
    assert blobs[0] == blobs[1], "wall clock leaked into speed matrix"
