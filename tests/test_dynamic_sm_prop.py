"""Property tests for dynamic SM allocation (§4.3): band, quantization, and
monotonicity invariants under arbitrary activity/headroom/band/step values,
plus scalar ⇄ vectorized equivalence.  Hypothesis-driven when available
(tests/_hyp.py shim); a deterministic dense grid sweep covers the same
invariants in environments without hypothesis."""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core.dynamic_sm import dynamic_sm, dynamic_sm_array

STEPS = (0.0, 0.05, 0.1, 0.25, 0.3, 1.0)


def _check_invariants(a_on, headroom, floor, cap, step):
    s = dynamic_sm(a_on, headroom=headroom, floor=floor, cap=cap, step=step)
    # 1. band: the share always lies in [floor, cap]
    assert floor - 1e-12 <= s <= cap + 1e-12
    # 2. quantization: on the step grid, or clamped at a band edge
    if step > 0:
        on_grid = abs(s / step - round(s / step)) < 1e-9
        at_edge = s in (floor, cap)
        assert on_grid or at_edge, (s, step, floor, cap)
    return s


# ---------------------------------------------------------------- hypothesis
@settings(max_examples=300, deadline=None)
@given(st.floats(-0.5, 1.5), st.floats(0.0, 0.5),
       st.floats(0.0, 0.5), st.floats(0.5, 1.0),
       st.sampled_from(STEPS))
def test_invariants_random(a_on, headroom, floor, cap, step):
    _check_invariants(a_on, headroom, floor, cap, step)


@settings(max_examples=200, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.sampled_from(STEPS))
def test_monotone_in_activity(a1, a2, step):
    """More online activity never grants the offline partner MORE SMs."""
    lo, hi = sorted((a1, a2))
    assert (dynamic_sm(hi, step=step) <= dynamic_sm(lo, step=step) + 1e-12)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.floats(-0.5, 1.5), min_size=1, max_size=64),
       st.sampled_from(STEPS))
def test_scalar_vector_equivalence_random(acts, step):
    vec = dynamic_sm_array(np.array(acts), step=step)
    ref = np.array([dynamic_sm(a, step=step) for a in acts])
    assert np.array_equal(vec, ref)


# ------------------------------------------------- deterministic grid sweeps
def test_invariants_grid():
    acts = np.linspace(-0.5, 1.5, 201)
    for floor, cap in ((0.1, 0.9), (0.0, 1.0), (0.15, 0.7), (0.25, 0.25)):
        for step in STEPS:
            for headroom in (0.0, 0.05, 0.2):
                for a in acts:
                    _check_invariants(float(a), headroom, floor, cap, step)


def test_scalar_vector_equivalence_grid():
    acts = np.linspace(-0.5, 1.5, 401)
    for step in STEPS:
        vec = dynamic_sm_array(acts, step=step)
        ref = np.array([dynamic_sm(float(a), step=step) for a in acts])
        assert np.array_equal(vec, ref), step


def test_monotone_grid():
    acts = np.linspace(0.0, 1.0, 301)
    for step in STEPS:
        shares = [dynamic_sm(float(a), step=step) for a in acts]
        assert all(b <= a + 1e-12 for a, b in zip(shares, shares[1:])), step


def test_degenerate_band_is_constant():
    """floor == cap pins the share regardless of activity or step."""
    for a in (0.0, 0.33, 1.0):
        assert dynamic_sm(a, floor=0.4, cap=0.4) == pytest.approx(0.4)


def test_invalid_band_rejected():
    with pytest.raises(ValueError):
        dynamic_sm(0.5, floor=0.8, cap=0.2)
    with pytest.raises(ValueError):
        dynamic_sm_array(np.array([0.5]), step=float("nan"))


def test_complementary_examples():
    """Fig. 8's headline behavior: 20% online -> 80% offline (within
    headroom+quantization), 80% online -> 20%."""
    assert dynamic_sm(0.2) == pytest.approx(0.8, abs=0.1)
    assert dynamic_sm(0.8) == pytest.approx(0.2, abs=0.1)


def test_hypothesis_status_documented():
    # not an invariant — just surfaces whether the property half ran
    assert HAVE_HYPOTHESIS in (True, False)
