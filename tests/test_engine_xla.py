"""Cross-engine parity: the compiled (XLA) tick engine must be
byte-identical to the numpy engine — state trajectories, SimResults, and
whole scenario reports — plus unit coverage for the pieces that make that
possible (block evaluation of the QPS bank, the LRU predictor memo, the
incremental matcher's warm==cold exactness)."""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.core.predictor import build_speed_predictor
from repro.core.simulator import ClusterSim, SimConfig

pytestmark = pytest.mark.slow  # compiled-engine suite: jit compiles inside


@pytest.fixture(scope="module")
def predictor():
    # A100 included: the hetero-pool scenarios schedule onto it
    return build_speed_predictor(gpu_types=("T4", "A10", "A100"), n=150,
                                 epochs=5)


def _lockstep(cfg_kw, predictor, n_ticks):
    from repro.policies import resolve
    p = (predictor
         if resolve(cfg_kw.get("policy", "muxflow")).needs_predictor
         else None)
    a = ClusterSim(SimConfig(engine="numpy", **cfg_kw), p)
    b = ClusterSim(SimConfig(engine="xla", **cfg_kw), p)
    ta = tb = 0.0
    for k in range(n_ticks):
        ta = a.step(ta)
        tb = b.step(tb)
        sa, sb = a.state, b.state
        for f in ("has_job", "model_idx", "sm_share", "progress",
                  "checkpoint", "wall", "duration", "failed_until",
                  "outage_until"):
            assert np.array_equal(getattr(sa, f), getattr(sb, f)), (k, f)
        assert np.array_equal(a.monitor.state, b.monitor.state), k
        assert np.array_equal(a.monitor._readmit_at, b.monitor._readmit_at,
                              equal_nan=True), k
        assert np.array_equal(a.monitor._ol_times, b.monitor._ol_times), k
        assert np.array_equal(a.monitor._ol_ptr, b.monitor._ol_ptr), k
        assert [sp.job_id for sp in a.pending] == \
               [sp.job_id for sp in b.pending], k
    return a, b


def test_lockstep_state_bitwise_under_heavy_faults(predictor):
    """Every tick's full state must match bit-for-bit, through failure,
    error, completion, requeue, and monitor-eviction paths."""
    a, b = _lockstep(
        dict(policy="muxflow", n_devices=60, horizon_s=2 * 3600.0,
             trace="D", seed=11, device_mtbf_h=2.0, device_repair_s=300.0,
             error_rate_per_job_hour=1.0, graceful_exit=False),
        predictor, n_ticks=240)
    assert a.errors_injected > 0
    assert dataclasses.asdict(a.finalize(240 * 30.0)) == \
        dataclasses.asdict(b.finalize(240 * 30.0))


@pytest.mark.parametrize("policy", ["muxflow", "time-sharing",
                                    "pb-time-sharing", "tally-priority",
                                    "static-partition", "online-only"])
def test_simresults_byte_identical_per_policy(policy, predictor):
    kw = dict(policy=policy, n_devices=48, horizon_s=3 * 3600.0,
              trace="C", seed=4)
    from repro.policies import resolve
    p = predictor if resolve(policy).needs_predictor else None
    r_np = ClusterSim(SimConfig(engine="numpy", **kw), p).run()
    r_x = ClusterSim(SimConfig(engine="xla", **kw), p).run()
    assert dataclasses.asdict(r_np) == dataclasses.asdict(r_x)


def test_scenario_reports_byte_identical_matrix(predictor):
    """Acceptance: every registered scenario's JSON report is byte-for-byte
    identical across engines at the same seed (small shapes; the
    ``calibrated`` scenario runs with its measured provider against a saved
    smoke matrix via the process-wide default)."""
    from repro.cluster.control import run_scenario
    from repro.cluster.scenario import SCENARIOS
    for name in sorted(SCENARIOS):
        reps = {}
        for engine in ("numpy", "xla"):
            reps[engine] = json.dumps(
                run_scenario(name, predictor=predictor, n_devices=32,
                             hours=0.5, seed=0, engine=engine),
                sort_keys=True)
        assert reps["numpy"] == reps["xla"], name


def test_block_and_per_tick_modes_agree(predictor):
    """ClusterSim.run() (lax.scan tick blocks) and externally driven
    step() loops (T=1 kernels) must produce identical results."""
    kw = dict(policy="muxflow", n_devices=48, horizon_s=2 * 3600.0,
              trace="B", seed=2, engine="xla")
    r_blocks = ClusterSim(SimConfig(**kw), predictor).run()
    sim = ClusterSim(SimConfig(**kw), predictor)
    t = 0.0
    for _ in range(int(kw["horizon_s"] / 30.0)):
        t = sim.step(t)
    r_steps = sim.finalize(t)
    assert dataclasses.asdict(r_blocks) == dataclasses.asdict(r_steps)


def test_engines_agree_with_inexact_tick(predictor):
    """tick_s values that are not exactly representable (0.7) accumulate
    float drift in the per-tick time sequence; the xla run() block
    boundaries must replay the numpy engine's accumulated-float scheduling
    predicate, not an arithmetic shortcut, to stay byte-identical."""
    kw = dict(policy="muxflow", n_devices=32, horizon_s=280.0, tick_s=0.7,
              schedule_interval_s=2.1, trace="C", seed=1)
    r_np = ClusterSim(SimConfig(engine="numpy", **kw), predictor).run()
    r_x = ClusterSim(SimConfig(engine="xla", **kw), predictor).run()
    assert dataclasses.asdict(r_np) == dataclasses.asdict(r_x)


def test_engine_name_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        ClusterSim(SimConfig(policy="time-sharing", engine="cuda"))


# ------------------------------------------------------------------ pieces
def test_qps_block_rows_bitwise_equal_per_tick():
    from repro.core.traces import OnlineQPS, QPSBank
    rng = np.random.default_rng(5)
    bank = QPSBank([OnlineQPS(rng) for _ in range(128)])
    ts = 13.5 + np.arange(48) * 30.0
    blk = bank.qps_block(ts)
    for j in (0, 7, 31, 47):
        row = bank.qps(float(ts[j]))
        assert np.array_equal(row.view(np.uint64), blk[j].view(np.uint64))


def test_error_kind_thresholds_match_scalar_mapping():
    """The engines' vectorized uniform→kind mapping must agree with
    errors.error_from_uniform everywhere, including the thresholds."""
    from repro.core.errors import error_from_uniform
    sim = ClusterSim(SimConfig(policy="time-sharing", n_devices=4,
                               horizon_s=60.0))
    us = np.concatenate([np.linspace(0.0, 0.999999, 5001),
                         sim._err_thresh - 1e-12, sim._err_thresh[:-1]])
    us = np.clip(us, 0.0, 1.0 - 1e-15)
    r = us * sim._err_total
    vec = np.minimum((r[:, None] > sim._err_thresh[None, :]).sum(axis=1),
                     len(sim._err_kinds) - 1)
    for u, k in zip(us, vec):
        assert sim._err_kinds[int(k)] is error_from_uniform(float(u)), u


def test_engine_x64_is_scoped(predictor):
    """The xla engine's float64 kernels must not leak jax's x64 mode into
    the rest of the process: the (float32) speed predictor predicts
    bitwise-identically before and after engine runs, and the global flag
    stays off — otherwise unrelated float32 code (models, serving) would
    silently change behavior whenever the engine ran."""
    import jax
    feats = np.random.default_rng(0).uniform(0, 1, (37, 9)).astype(
        np.float32)
    before = predictor.predict("T4", feats).tobytes()
    ClusterSim(SimConfig(policy="time-sharing", n_devices=16,
                         horizon_s=600.0, engine="xla")).run()
    assert not jax.config.jax_enable_x64
    assert predictor.predict("T4", feats).tobytes() == before


# --------------------------------------------------------------- matcher
def _scheduler_instance(rng, n, m, u=4):
    vals = np.round(rng.uniform(0, 1, (n, u)), 2)
    grp = rng.integers(0, u, m)
    ids = np.sort(rng.choice(10 * n, size=n, replace=False))
    return vals, grp, ids


def test_incremental_matcher_warm_equals_cold():
    from repro.core.matching import IncrementalMatcher
    rng = np.random.default_rng(0)
    warm = IncrementalMatcher(shard_size=128)
    vals, grp, ids = _scheduler_instance(rng, 1500, 600)
    for rnd in range(6):
        # drift a few rows and churn the columns a little each round
        touch = rng.random(vals.shape[0]) < 0.02
        vals[touch] = np.round(rng.uniform(0, 1, (int(touch.sum()),
                                                  vals.shape[1])), 2)
        grp = np.concatenate([grp[5:], rng.integers(0, 4, 5)])
        cold = IncrementalMatcher(shard_size=128)
        assert warm.match(vals, grp, ids) == cold.match(vals, grp, ids), rnd
    assert warm.rounds == 6


def test_incremental_matcher_reuses_clean_shards():
    from repro.core.matching import IncrementalMatcher
    rng = np.random.default_rng(1)
    vals, grp, ids = _scheduler_instance(rng, 2000, 800)
    m = IncrementalMatcher(shard_size=128)
    first = m.match(vals, grp, ids)
    again = m.match(vals, grp, ids)          # identical round
    assert first == again
    # round 1 is a full (cold) solve; round 2 reuses every shard
    assert m.full_solves == 1
    stats = m.stats()
    assert stats["rounds"] == 2
    assert stats["shards_reused"] == stats["shards_solved"] > 0


def test_incremental_matcher_full_solve_on_heavy_churn():
    from repro.core.matching import IncrementalMatcher
    rng = np.random.default_rng(2)
    m = IncrementalMatcher(shard_size=128, full_solve_dirty_frac=0.5)
    vals, grp, ids = _scheduler_instance(rng, 1500, 600)
    m.match(vals, grp, ids)
    vals2 = np.round(rng.uniform(0, 1, vals.shape), 2)   # everything moved
    cold = IncrementalMatcher(shard_size=128)
    assert m.match(vals2, grp, ids) == cold.match(vals2, grp, ids)
    assert m.full_solves >= 1


def test_incremental_matcher_validity_and_quality():
    from repro.core.matching import (IncrementalMatcher, km_match,
                                     matching_weight)
    rng = np.random.default_rng(7)
    for n, m_cols in ((500, 200), (300, 700)):
        vals = rng.uniform(0, 1, (n, 4))
        grp = rng.integers(0, 4, m_cols)
        w = vals[:, grp]
        pairs = IncrementalMatcher(shard_size=128).match(
            vals, grp, np.arange(n))
        rows = [r for r, _ in pairs]
        cols = [c for _, c in pairs]
        assert len(set(rows)) == len(rows) and len(set(cols)) == len(cols)
        assert all(0 <= r < n and 0 <= c < m_cols for r, c in pairs)
        dense = matching_weight(w, km_match(w))
        assert matching_weight(w, pairs) >= 0.97 * dense


def test_incremental_matcher_small_problem_is_exact():
    from repro.core.matching import (IncrementalMatcher, km_match,
                                     matching_weight)
    rng = np.random.default_rng(3)
    vals = rng.uniform(0, 1, (40, 4))
    grp = rng.integers(0, 4, 30)
    pairs = IncrementalMatcher(shard_size=256).match(vals, grp,
                                                     np.arange(40))
    w = vals[:, grp]
    assert matching_weight(w, pairs) == pytest.approx(
        matching_weight(w, km_match(w)), rel=1e-9)


# ----------------------------------------------------------- LRU predictor
def test_cached_predictor_lru_bound_and_stats(predictor):
    from repro.core.predictor import CachedSpeedPredictor
    cached = CachedSpeedPredictor(predictor, quantum=0.0, max_entries=64)
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 1, (64, 9)).astype(np.float32)
    cached.predict("T4", a)
    assert len(cached._cache) == 64 and cached.evictions == 0
    b = rng.uniform(0, 1, (32, 9)).astype(np.float32)
    cached.predict("T4", b)
    # bound holds; the 32 oldest rows were evicted LRU-first
    assert len(cached._cache) == 64
    assert cached.evictions == 32
    # rows still resident answer from cache, and hits refresh recency
    before = cached.hits
    out1 = cached.predict("T4", b)
    assert cached.hits == before + 32
    np.testing.assert_array_equal(out1, cached.predict("T4", b))
    stats = cached.stats()
    for k in ("hits", "misses", "evictions", "entries", "hit_rate"):
        assert k in stats
    assert stats["entries"] == 64
