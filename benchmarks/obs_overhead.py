"""Observability overhead: obs-off vs obs-on wall time for diurnal-mixed.

The acceptance budget for the observability plane is ≤5% added wall time on
the flagship campaign's run phase (metrics + trace streaming + window
alerting enabled, full window rollups and span folding).  This suite
measures it: the same ``diurnal-mixed`` scenario runs with observability
off and on (one shared, pre-built predictor; a warm-up run first so
one-time jit compiles don't land in either measurement), and a third run
profiles the tick-phase breakdown
(inputs/predict/match/dense_core/account/serving) — the *only* place those
wall-clock phase numbers are allowed to appear (they are quarantined from
every deterministic artifact).

CI gates the smoke-shape ratio at ≤1.25x (soft: tiny shapes carry fixed
per-run costs the flagship amortizes away; the 1.05 budget is judged on
the full shape).

  PYTHONPATH=src python benchmarks/obs_overhead.py          # full 20k x 12h
  PYTHONPATH=src python benchmarks/obs_overhead.py --smoke  # tiny CI shape
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time


def _scenario(smoke: bool):
    from repro.cluster.scenario import scenario_by_name
    sc = scenario_by_name("diurnal-mixed")
    if smoke:
        # big enough that per-tick work dominates per-run fixed costs —
        # a 64-device half-hour run finishes in ~30ms and the off/on ratio
        # is pure timer noise; 6h keeps walls ~0.5s so the CI ratio gate
        # isn't dominated by shared-runner jitter
        return sc.with_overrides(n_devices=512, hours=6.0, seed=0,
                                 predictor_samples=150, predictor_epochs=5)
    return sc.with_overrides(n_devices=20000, hours=12.0, seed=0)


def _build_predictor(sc):
    """One predictor shared by every cell (the measured phase is run())."""
    from repro.cluster.fleet import FleetSpec
    from repro.policies import resolve
    pol = resolve(sc.policy)
    if not pol.needs_predictor:
        return None
    fleet = FleetSpec(sc.n_devices, sc.pools) if sc.pools else None
    gpu_types = (fleet.gpu_types if fleet
                 else tuple(dict.fromkeys(sc.gpu_types)))
    return pol.build_predictor(gpu_types, samples=sc.predictor_samples,
                               epochs=sc.predictor_epochs, seed=0)


def _run_cell(sc, predictor, obs=None, profiler=None) -> tuple[float, object]:
    from repro.cluster.control import ControlPlane
    cp = ControlPlane(sc, predictor=predictor, obs=obs)
    if profiler is not None:
        cp.sim.attach_phases(profiler)
    t0 = time.perf_counter()
    cp.run()
    return time.perf_counter() - t0, cp


def run_json(smoke: bool = False, pairs: int = 2) -> dict:
    from repro.obs import ObsConfig, PhaseProfiler
    sc = _scenario(smoke)
    t0 = time.perf_counter()
    predictor = _build_predictor(sc)
    t_pred = time.perf_counter() - t0
    with tempfile.TemporaryDirectory(prefix="obs_overhead_") as tmp:
        obs = ObsConfig(metrics_out=os.path.join(tmp, "metrics.jsonl"),
                        trace_out=os.path.join(tmp, "trace.jsonl"),
                        alerts_out=os.path.join(tmp, "incidents.jsonl"))
        _run_cell(sc, predictor)                      # warm-up (jit, caches)
        # single paired runs are noisy at flagship scale (shared-host VM
        # jitter moves walls by ~10%); alternate off/on pairs and take the
        # min wall of each — the standard de-noising for wall benchmarks
        off_walls, on_walls = [], []
        for _ in range(max(pairs, 1)):
            w, _cp = _run_cell(sc, predictor)
            off_walls.append(w)
            w, cp_on = _run_cell(sc, predictor, obs=obs)
            on_walls.append(w)
        off_wall, on_wall = min(off_walls), min(on_walls)
        obs_summary = cp_on.obs.summary()
        alerts_summary = cp_on.obs.incidents_summary()
        prof = PhaseProfiler()
        _run_cell(sc, predictor, obs=obs, profiler=prof)
    base = {"scenario": sc.name, "n_devices": sc.n_devices,
            "horizon_s": sc.horizon_seconds(), "engine": sc.engine}
    ratio = on_wall / max(off_wall, 1e-9)
    return {
        "cells": [
            {**base, "obs": False, "wall_s": off_wall},
            {**base, "obs": True, "wall_s": on_wall,
             "metrics_rows": obs_summary["metrics"]["rows"],
             "metrics_windows": obs_summary["metrics"]["windows"],
             "trace_rows": obs_summary["trace"]["rows"],
             "alert_rows": alerts_summary["rows"],
             "incidents": alerts_summary["total"]},
        ],
        "overhead": {
            "off_wall_s": off_wall,
            "on_wall_s": on_wall,
            "off_walls_s": off_walls,
            "on_walls_s": on_walls,
            "ratio": ratio,
            # the ISSUE-7 acceptance budget; advisory in smoke mode (tiny
            # shapes are dominated by fixed per-run costs and timer noise)
            "within_budget": bool(ratio <= 1.05),
        },
        # wall-clock tick-phase breakdown — BENCH_sim.json is the one
        # artifact this may enter (never deterministic reports/exports)
        "tick_phases": prof.summary(),
        "phases": {"predictor_train_s": t_pred},
        "headline_walls": {"diurnal_obs_off": off_wall,
                           "diurnal_obs_on": on_wall},
    }


def gate(threshold: float = 1.25, attempts: int = 3, pairs: int = 4) -> int:
    """The soft CI gate: pass if ANY attempt's min-paired ratio is within
    ``threshold``.  Shared runners jitter walls by 2x in the worst case and
    that jitter overwhelmingly *inflates* a single measured ratio, so
    best-of-attempts rejects noise while a genuine hot-path regression
    (true ratio above threshold) fails every attempt."""
    best = float("inf")
    for i in range(attempts):
        ratio = run_json(smoke=True, pairs=pairs)["overhead"]["ratio"]
        best = min(best, ratio)
        print(f"gate attempt {i + 1}/{attempts}: ratio {ratio:.3f} "
              f"(threshold {threshold})")
        if ratio <= threshold:
            print(f"obs overhead gate OK (ratio {ratio:.3f} <= {threshold})")
            return 0
    print(f"obs overhead gate FAIL: best ratio {best:.3f} > {threshold} "
          f"across {attempts} attempts")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="soft CI gate: fail only if every attempt's "
                         "obs-on/off ratio exceeds the budget")
    ap.add_argument("--gate-threshold", type=float, default=1.25)
    args = ap.parse_args(argv)
    if args.gate:
        return gate(threshold=args.gate_threshold)
    doc = run_json(smoke=args.smoke)
    ov = doc["overhead"]
    print(f"obs off {ov['off_wall_s']:.2f}s  on {ov['on_wall_s']:.2f}s  "
          f"ratio {ov['ratio']:.3f}  "
          f"{'OK' if ov['within_budget'] else 'OVER BUDGET'}")
    for name, row in doc["tick_phases"]["phases"].items():
        print(f"  phase {name:12s} {row['wall_s']:.3f}s x{row['calls']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
