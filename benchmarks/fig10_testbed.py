"""Figure 10 — testbed experiment: MuxFlow vs Online-only detailed metrics
(online latency, offline normalized throughput, GPU utilization timelines).

Paper headline numbers: avg latency +16.0 %, p99 +15.3 %, up to 86.42 % GPU
resource to offline workloads, GPU util ×4.0, SM activity ×4.7, memory ×1.5,
1.5 % of offline executions evicted, zero error propagation.
"""
from __future__ import annotations

# rides the repro.cluster control plane (neutral passthrough: same
# engine + RNG stream as repro.core.simulator.run_policy)
from repro.cluster.control import run_policy_scenario as run_policy
from .bench_lib import emit, timeit
from .predictor_cache import get_predictor

CFG = dict(n_devices=120, horizon_s=8 * 3600.0, tick_s=60.0, trace="C", seed=0)


def run() -> None:
    pred = get_predictor()
    import time
    t0 = time.perf_counter()
    base = run_policy("online-only", None, **CFG)
    mux = run_policy("muxflow", pred, **CFG)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig10_online_avg_latency_increase", us,
         f"{(mux.avg_slowdown-1)*100:.1f}% (paper 16.0%)")
    emit("fig10_online_p99_latency_increase", 0.0,
         f"{(mux.p99_latency_ms/base.p99_latency_ms-1)*100:.1f}% (paper 15.3%)")
    emit("fig10_offline_norm_tput", 0.0,
         f"{mux.avg_norm_tput:.3f}")
    emit("fig10_oversold_gpu", 0.0,
         f"{mux.oversold_gpu*100:.1f}% (paper up to 86.42%)")
    emit("fig10_gpu_util_ratio", 0.0,
         f"{mux.gpu_util/max(base.gpu_util,1e-9):.2f}x (paper 4.0x)")
    emit("fig10_sm_activity_ratio", 0.0,
         f"{mux.sm_activity/max(base.sm_activity,1e-9):.2f}x (paper 4.7x)")
    emit("fig10_mem_ratio", 0.0,
         f"{mux.mem_used/max(base.mem_used,1e-9):.2f}x (paper 1.5x)")
    emit("fig10_eviction_frac", 0.0,
         f"{mux.eviction_frac*100:.2f}% (paper 1.5%)")
    emit("fig10_error_propagation", 0.0,
         f"{mux.errors_propagated}/{mux.errors_injected} (paper: none)")
