"""One trained speed predictor shared by all simulator benchmarks."""
from __future__ import annotations

_PRED = None


def get_predictor():
    global _PRED
    if _PRED is None:
        from repro.core.predictor import build_speed_predictor
        _PRED = build_speed_predictor(gpu_types=("T4", "A10"), n=1500, epochs=60)
    return _PRED
