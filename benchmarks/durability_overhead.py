"""Durability overhead: WAL append throughput, snapshot cost, replay speed.

The durable control plane must be cheap enough to leave on: the WAL sink
rides every ``EventBus.emit`` and snapshots ride tick boundaries.  This
suite measures the three costs that matter:

* **append** — raw event-store throughput (events/s) for both backends,
  synthetic events in a temp directory, fsync'd once at the end (the same
  discipline the runner uses: buffered appends, fsync at snapshots).
* **snapshot** — capture + pickle latency and snapshot size for a
  full-featured control plane at end-of-run state, plus the restore cost.
* **recovery** — the headline walls: the same scenario plain vs durable
  (sink + snapshots + manifests on), and replay (resume from the earliest
  retained snapshot) vs the live run it reconstructs.

  PYTHONPATH=src python benchmarks/durability_overhead.py          # full
  PYTHONPATH=src python benchmarks/durability_overhead.py --smoke  # CI shape
"""
from __future__ import annotations

import argparse
import glob
import os
import pickle
import re
import sys
import tempfile
import time


def _scenario(smoke: bool):
    from repro.cluster.scenario import Scenario
    if smoke:
        return Scenario(name="durability-bench", policy="time-sharing",
                        n_devices=128, hours=2.0, seed=0, trace="C")
    return Scenario(name="durability-bench", policy="time-sharing",
                    n_devices=2000, hours=6.0, seed=0, trace="C")


def _events(n: int):
    from repro.cluster.events import Event, EventKind
    kinds = list(EventKind)
    return [Event(seq=i, t=30.0 * i, kind=kinds[i % len(kinds)],
                  device=i % 512, job=i % 64,
                  data=(("w", 0.25 * (i % 17)), ("n", i)))
            for i in range(n)]


def _bench_append(n: int) -> dict:
    from repro.durability import open_store
    evs = _events(n)
    out = {}
    for backend in ("jsonl", "sqlite"):
        with tempfile.TemporaryDirectory(prefix="durab_append_") as tmp:
            store = open_store(os.path.join(tmp, "ev"), backend,
                               segment_events=50_000)
            t0 = time.perf_counter()
            for ev in evs:
                store.append(ev)
            store.flush(fsync=True)
            wall = time.perf_counter() - t0
            store.close()
        out[backend] = {"n_events": n, "wall_s": wall,
                        "events_per_s": n / max(wall, 1e-9)}
    return out


def _bench_snapshot(cp, store, horizon_s: float, n_ticks: int) -> dict:
    from repro.cluster.control import ControlPlane
    from repro.durability import capture_control, restore_control
    t0 = time.perf_counter()
    snap = capture_control(cp, horizon_s, n_ticks)
    capture_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    blob = pickle.dumps(snap)
    pickle_s = time.perf_counter() - t0
    fresh = ControlPlane(cp.scenario)
    t0 = time.perf_counter()
    restore_control(fresh, pickle.loads(blob), store=store)
    restore_s = time.perf_counter() - t0
    return {"capture_s": capture_s, "pickle_s": pickle_s,
            "restore_s": restore_s, "bytes": len(blob)}


def run_json(smoke: bool = False) -> dict:
    from repro.cluster.control import ControlPlane
    from repro.durability import DurableRun, resume_run
    sc = _scenario(smoke)
    n_append = 20_000 if smoke else 200_000
    append = _bench_append(n_append)

    # plain run (no durability) — the baseline wall
    cp = ControlPlane(sc)
    t0 = time.perf_counter()
    cp.run()
    plain_wall = time.perf_counter() - t0

    with tempfile.TemporaryDirectory(prefix="durab_bench_") as tmp:
        rundir = os.path.join(tmp, "run")
        # durable run: WAL sink + snapshots + manifest refreshes all on.
        # keep every snapshot so replay below can pin the earliest one.
        run = DurableRun.create(sc, rundir, snapshot_every_s=600.0,
                                keep_snapshots=10_000)
        t0 = time.perf_counter()
        run.execute()
        durable_wall = time.perf_counter() - t0
        n_ticks = run._n_ticks()
        snapshot = _bench_snapshot(run.cp, run.store, sc.horizon_seconds(),
                                   n_ticks)
        snaps = sorted(glob.glob(
            os.path.join(rundir, "snapshots", "snap-*.pkl")))
        first_tick = int(re.search(r"snap-(\d+)", snaps[0]).group(1))
        run.store.close()
        t0 = time.perf_counter()
        resumed = resume_run(rundir, at_tick=first_tick)
        replay_wall = time.perf_counter() - t0
        resumed.store.close()
        assert resumed.report == run.cp.report()

    recovery = {
        "plain_wall_s": plain_wall,
        "durable_wall_s": durable_wall,
        "durable_ratio": durable_wall / max(plain_wall, 1e-9),
        "replay_wall_s": replay_wall,
        "replayed_ticks": n_ticks - first_tick,
        "n_ticks": n_ticks,
        "n_events": run.store.count(),
        "snapshots_taken": run.snapshots_taken,
    }
    return {
        "scenario": {"n_devices": sc.n_devices,
                     "horizon_s": sc.horizon_seconds(),
                     "policy": "time-sharing"},
        "append": append,
        "snapshot": snapshot,
        "recovery": recovery,
        "phases": {"plain_run_s": plain_wall, "durable_run_s": durable_wall,
                   "replay_s": replay_wall},
        "headline_walls": {"durable_run": durable_wall,
                           "replay": replay_wall},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    doc = run_json(smoke=args.smoke)
    for backend, row in doc["append"].items():
        print(f"append {backend:7s} {row['events_per_s']:,.0f} events/s "
              f"({row['n_events']} events in {row['wall_s']:.3f}s)")
    sn = doc["snapshot"]
    print(f"snapshot capture {sn['capture_s']*1e3:.1f}ms  pickle "
          f"{sn['pickle_s']*1e3:.1f}ms  restore {sn['restore_s']*1e3:.1f}ms "
          f" size {sn['bytes']/1e6:.2f}MB")
    rec = doc["recovery"]
    print(f"plain {rec['plain_wall_s']:.2f}s  durable "
          f"{rec['durable_wall_s']:.2f}s (x{rec['durable_ratio']:.3f})  "
          f"replay {rec['replay_wall_s']:.2f}s for "
          f"{rec['replayed_ticks']}/{rec['n_ticks']} ticks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
