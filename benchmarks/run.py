"""Benchmark harness: one module per paper figure/table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (bench_lib.emit).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig11 fig4 # subset
"""
from __future__ import annotations

import sys
import time
import traceback

SUITES = [
    ("fig4", "benchmarks.fig4_sharing"),
    ("fig10", "benchmarks.fig10_testbed"),
    ("fig11", "benchmarks.fig11_comparison"),
    ("fig12", "benchmarks.fig12_predictor"),
    ("fig13", "benchmarks.fig13_ablation"),
    ("fig14", "benchmarks.fig14_15_deployment"),
    ("overhead", "benchmarks.overhead_matching"),
    ("simscale", "benchmarks.bench_sim_scale"),
    ("kernels", "benchmarks.kernel_bench"),
]


def main() -> None:
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    t_all = time.time()
    failures = 0
    for key, mod_name in SUITES:
        if want and key not in want:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===")
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception:  # noqa: BLE001 — report, continue
            failures += 1
            print(f"# FAILED {mod_name}")
            traceback.print_exc()
        print(f"# {mod_name} took {time.time()-t0:.1f}s")
    print(f"# total {time.time()-t_all:.1f}s, failures={failures}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
