"""Benchmark harness: one module per paper figure/table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (bench_lib.emit), or — with
``--json`` — writes the schema-versioned ``BENCH_sim.json`` perf-trajectory
artifact (fixed seeds, wall + per-phase breakdown for bench_sim_scale,
overhead_matching, and kernel_bench) that CI uploads and diffs against the
committed baseline.

  PYTHONPATH=src python -m benchmarks.run              # all, CSV
  PYTHONPATH=src python -m benchmarks.run fig11 fig4   # subset, CSV
  PYTHONPATH=src python -m benchmarks.run --json BENCH_sim.json --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

SUITES = [
    ("fig4", "benchmarks.fig4_sharing"),
    ("fig10", "benchmarks.fig10_testbed"),
    ("fig11", "benchmarks.fig11_comparison"),
    ("fig12", "benchmarks.fig12_predictor"),
    ("fig13", "benchmarks.fig13_ablation"),
    ("fig14", "benchmarks.fig14_15_deployment"),
    ("overhead", "benchmarks.overhead_matching"),
    ("simscale", "benchmarks.bench_sim_scale"),
    ("kernels", "benchmarks.kernel_bench"),
]

# the perf-trajectory suites: every module here exposes run_json(smoke)
JSON_SUITES = [
    ("bench_sim_scale", "benchmarks.bench_sim_scale"),
    ("overhead_matching", "benchmarks.overhead_matching"),
    ("kernel_bench", "benchmarks.kernel_bench"),
]


def run_csv(want: set[str]) -> int:
    print("name,us_per_call,derived")
    t_all = time.time()
    failures = 0
    for key, mod_name in SUITES:
        if want and key not in want:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===")
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run()
        except Exception:  # noqa: BLE001 — report, continue
            failures += 1
            print(f"# FAILED {mod_name}")
            traceback.print_exc()
        print(f"# {mod_name} took {time.time()-t0:.1f}s")
    print(f"# total {time.time()-t_all:.1f}s, failures={failures}")
    return failures


def run_json_artifact(path: str, smoke: bool) -> int:
    import importlib

    from benchmarks.bench_schema import check_schema, make_artifact
    suites = {}
    failures = 0
    for key, mod_name in JSON_SUITES:
        t0 = time.time()
        print(f"# === {mod_name} (json) ===", file=sys.stderr)
        try:
            suites[key] = importlib.import_module(mod_name).run_json(
                smoke=smoke)
        except Exception:  # noqa: BLE001 — report, continue
            failures += 1
            traceback.print_exc()
        print(f"# {mod_name} took {time.time()-t0:.1f}s", file=sys.stderr)
    doc = make_artifact(suites, smoke=smoke)
    problems = [] if failures else check_schema(doc)
    for p in problems:
        print(f"# SCHEMA: {p}", file=sys.stderr)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)
    return failures + len(problems)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suites", nargs="*", help="CSV-mode suite subset")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_sim.json perf artifact instead "
                         "of CSV rows")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes for --json")
    args = ap.parse_args(argv)
    if args.json:
        failures = run_json_artifact(args.json, smoke=args.smoke)
    else:
        failures = run_csv(set(args.suites))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
