"""Deprecated benchmark-harness entry point.

``python -m benchmarks.run`` is now a thin delegate of the unified CLI —
``python -m repro bench`` (see :mod:`repro.cli`, which owns the suite
tables).  Flags and stdout bytes (CSV rows / the ``BENCH_sim.json``
artifact) are unchanged; a deprecation note goes to stderr.
"""
from __future__ import annotations

import sys

from repro.cli import (BENCH_JSON_SUITES as JSON_SUITES,  # noqa: F401
                       BENCH_SUITES as SUITES,
                       bench_main, deprecation_note)


def main(argv=None) -> None:
    deprecation_note("python -m benchmarks.run", "python -m repro bench")
    rc = bench_main(argv, prog="python -m benchmarks.run")
    if rc:
        raise SystemExit(rc)


if __name__ == "__main__":
    main()
