"""Figure 12 — speed-predictor accuracy vs MLP architecture.

(a) hidden size 64→1024 at 4 layers: similar accuracy/convergence.
(b) layers 2→8 at hidden 64: 4 layers is the sweet spot.
(c) measured-pair evaluation: the seed trained AND evaluated the predictor
    on the synthetic interference formula (circular).  With the profiling
    subsystem the eval set comes from measured workload pairs, and the sweep
    contrasts train-on-synthetic vs train-on-measured error against it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor import make_dataset, mlp_apply, train_predictor
from .bench_lib import emit, timeit


def run() -> None:
    rng = np.random.default_rng(0)
    feats, targets = make_dataset(rng, n=1500)
    # (a) hidden sweep
    for hidden in (64, 256, 1024):
        import time
        t0 = time.perf_counter()
        _, hist = train_predictor(jax.random.PRNGKey(0), feats, targets,
                                  hidden=hidden, layers=4, epochs=50)
        emit(f"fig12a_hidden_{hidden}", (time.perf_counter() - t0) * 1e6,
             f"val_mae={hist['val_mae'][-1]:.4f}")
    # (b) layers sweep
    maes = {}
    for layers in (2, 4, 6, 8):
        import time
        t0 = time.perf_counter()
        _, hist = train_predictor(jax.random.PRNGKey(0), feats, targets,
                                  hidden=64, layers=layers, epochs=50)
        maes[layers] = hist["val_mae"][-1]
        emit(f"fig12b_layers_{layers}", (time.perf_counter() - t0) * 1e6,
             f"val_mae={maes[layers]:.4f}")
    best = min(maes, key=maes.get)
    emit("fig12b_best_layers", 0.0, f"{best} (paper picks 4)")

    # (c) measured pairs: train-synthetic vs train-measured, same eval set
    from repro.profiling import default_matrix, make_measured_dataset
    matrix = default_matrix("smoke")
    m_train = make_measured_dataset(matrix, np.random.default_rng(1), n=1200)
    m_eval = make_measured_dataset(matrix, np.random.default_rng(2), n=400,
                                   noise=0.0)
    xe, ye = jnp.asarray(m_eval[0]), jnp.asarray(m_eval[1])

    def eval_mae(params):
        return float(jnp.mean(jnp.abs(mlp_apply(params, xe) - ye)))

    t0 = time.perf_counter()
    p_syn, _ = train_predictor(jax.random.PRNGKey(0), feats, targets,
                               hidden=64, layers=4, epochs=50)
    emit("fig12c_train_synthetic_eval_measured",
         (time.perf_counter() - t0) * 1e6, f"mae={eval_mae(p_syn):.4f}")
    t0 = time.perf_counter()
    p_meas, _ = train_predictor(jax.random.PRNGKey(0), *m_train,
                                hidden=64, layers=4, epochs=50)
    mae_meas = eval_mae(p_meas)
    emit("fig12c_train_measured_eval_measured",
         (time.perf_counter() - t0) * 1e6, f"mae={mae_meas:.4f}")
    emit("fig12c_measured_gain", 0.0,
         f"{eval_mae(p_syn) / max(mae_meas, 1e-9):.1f}x error reduction")
