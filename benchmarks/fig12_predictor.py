"""Figure 12 — speed-predictor accuracy vs MLP architecture.

(a) hidden size 64→1024 at 4 layers: similar accuracy/convergence.
(b) layers 2→8 at hidden 64: 4 layers is the sweet spot.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.predictor import make_dataset, train_predictor
from .bench_lib import emit, timeit


def run() -> None:
    rng = np.random.default_rng(0)
    feats, targets = make_dataset(rng, n=1500)
    # (a) hidden sweep
    for hidden in (64, 256, 1024):
        import time
        t0 = time.perf_counter()
        _, hist = train_predictor(jax.random.PRNGKey(0), feats, targets,
                                  hidden=hidden, layers=4, epochs=50)
        emit(f"fig12a_hidden_{hidden}", (time.perf_counter() - t0) * 1e6,
             f"val_mae={hist['val_mae'][-1]:.4f}")
    # (b) layers sweep
    maes = {}
    for layers in (2, 4, 6, 8):
        import time
        t0 = time.perf_counter()
        _, hist = train_predictor(jax.random.PRNGKey(0), feats, targets,
                                  hidden=64, layers=layers, epochs=50)
        maes[layers] = hist["val_mae"][-1]
        emit(f"fig12b_layers_{layers}", (time.perf_counter() - t0) * 1e6,
             f"val_mae={maes[layers]:.4f}")
    best = min(maes, key=maes.get)
    emit("fig12b_best_layers", 0.0, f"{best} (paper picks 4)")
