"""Figure 4 — pairwise sharing with MPS-style SM shares.

(a) online×offline model pairs at tuned shares: offline extra compute vs
    online slowdown (paper: up to +62 % offline at < 20 % online slowdown).
(b) SM-share sweep 10 %→100 % for one pair (paper: both workloads' normalized
    performance varies > 5×).
(m) measured cells: the same sweep read from the profiled speed matrix
    (executed jax_pallas workload pairs) instead of the analytic model.
"""
from __future__ import annotations

import numpy as np

from repro.core.interference import (OFFLINE_MODEL_PROFILES, online_profile,
                                     shared_performance)
from .bench_lib import emit, timeit


def run() -> None:
    # (a) pairs: use inference-as-online (paper uses VGG16/DenseNet201 inference)
    onlines = {"V-infer": online_profile("vision", 120.0),
               "D-infer": online_profile("translate", 80.0)}
    best_overall = 0.0
    for on_name, on in onlines.items():
        for off_name in ("VGG16", "DenseNet201"):
            off = OFFLINE_MODEL_PROFILES[off_name]
            best = (0.0, 1.0)
            for s in np.linspace(0.1, 0.9, 17):
                slow, tput = shared_performance(on, off, float(s))
                if slow <= 1.20 and tput > best[0]:
                    best = (tput, slow)
            us = timeit(lambda: shared_performance(on, off, 0.5), iters=5)
            emit(f"fig4a_pair_{on_name}-{off_name[:1]}_offline_tput", us,
                 f"{best[0]:.3f}@slow{best[1]:.3f}")
            best_overall = max(best_overall, best[0])
    emit("fig4a_best_offline_tput_at_slo1.2", 0.0,
         f"{best_overall:.3f} (paper: up to 0.62)")

    # (b) SM sweep for DenseNet-online / VGG16-offline
    on = onlines["D-infer"]
    off = OFFLINE_MODEL_PROFILES["VGG16"]
    tputs, slows = [], []
    for s in np.linspace(0.1, 1.0, 10):
        slow, tput = shared_performance(on, off, float(s))
        tputs.append(tput)
        slows.append(slow)
        emit(f"fig4b_sweep_sm{int(s*100):03d}", 0.0,
             f"off_tput={tput:.3f};on_slow={slow:.3f}")
    spread = max(tputs) / max(min(tputs), 1e-9)
    emit("fig4b_offline_perf_spread", 0.0,
         f"{spread:.1f}x (paper: >5x)")

    # (m) measured cells from the profiling subsystem's smoke speed matrix
    from repro.profiling import default_matrix
    matrix = default_matrix("smoke")
    best_measured = 0.0
    for pair in matrix.pairs:
        best = (0.0, 1.0)
        for slow_m, tput_m in zip(pair["online_slowdown"],
                                  pair["offline_tput"]):
            if slow_m <= 1.20 and tput_m > best[0]:
                best = (tput_m, slow_m)
        emit(f"fig4m_pair_{pair['online']}-{pair['offline']}_offline_tput",
             0.0, f"{best[0]:.3f}@slow{best[1]:.3f}")
        best_measured = max(best_measured, best[0])
    emit("fig4m_best_measured_tput_at_slo1.2", 0.0,
         f"{best_measured:.3f} (synthetic cell above; paper: up to 0.62)")
