"""Figure 11 — MuxFlow vs Online-only / Time-sharing / PB-time-sharing,
plus the related-work policies from the registry (Tally-style priority
slicing, ParvaGPU-style static partitioning).

Paper: MuxFlow improves average JCT by 1.10–2.24× and oversold GPU by
1.08–1.97× over the time-sharing baselines while slowing online < 20 %
(time-sharing slows online up to 50 %).
"""
from __future__ import annotations

import time

# rides the repro.cluster control plane (neutral passthrough: same
# engine + RNG stream as repro.core.simulator.run_policy)
from repro.cluster.control import run_policy_scenario as run_policy
from repro.policies import resolve

from .bench_lib import emit
from .predictor_cache import get_predictor

CFG = dict(n_devices=100, horizon_s=8 * 3600.0, tick_s=60.0, trace="B", seed=1)

BASELINES = ("online-only", "muxflow", "time-sharing", "pb-time-sharing")
NEW_POLICIES = ("tally-priority", "static-partition")


def run() -> None:
    pred = get_predictor()
    res = {}
    for pol in BASELINES + NEW_POLICIES:
        t0 = time.perf_counter()
        res[pol] = run_policy(pol,
                              pred if resolve(pol).needs_predictor else None,
                              **CFG)
        emit(f"fig11_sim_{pol}", (time.perf_counter() - t0) * 1e6,
             f"slow={res[pol].avg_slowdown:.3f};jct={res[pol].avg_jct_s:.0f}s;"
             f"oversold={res[pol].oversold_gpu:.3f};done={res[pol].n_finished}")
    mux = res["muxflow"]
    for base in ("time-sharing", "pb-time-sharing"):
        b = res[base]
        emit(f"fig11_jct_speedup_vs_{base}", 0.0,
             f"{b.avg_jct_s/max(mux.avg_jct_s,1e-9):.2f}x (paper 1.10-2.24x)")
        emit(f"fig11_oversold_gain_vs_{base}", 0.0,
             f"{mux.oversold_gpu/max(b.oversold_gpu,1e-9):.2f}x (paper 1.08-1.97x)")
    emit("fig11_online_slowdown_muxflow", 0.0,
         f"{(mux.avg_slowdown-1)*100:.1f}% (<20% required)")
    emit("fig11_online_slowdown_time_sharing", 0.0,
         f"{(res['time-sharing'].avg_slowdown-1)*100:.1f}% (paper: up to 50%)")
    # registry policies from related work: Tally-style slicing should
    # protect online even harder than MuxFlow (at an offline-tput cost);
    # a static MIG-like split trades elasticity for predictability
    for pol in NEW_POLICIES:
        r = res[pol]
        emit(f"fig11_vs_muxflow_{pol}", 0.0,
             f"slow={(r.avg_slowdown-1)*100:.1f}%(mux "
             f"{(mux.avg_slowdown-1)*100:.1f}%);oversold="
             f"{r.oversold_gpu:.3f}(mux {mux.oversold_gpu:.3f})")
    assert res["tally-priority"].avg_slowdown <= mux.avg_slowdown + 1e-6
