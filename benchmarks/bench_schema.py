"""The canonical bench-trajectory artifact: ``BENCH_sim.json``.

``python -m benchmarks.run --json BENCH_sim.json`` collects each perf
suite's structured results (fixed seeds, wall + per-phase breakdown) into
one schema-versioned document so perf regressions become diffable across
PRs — CI uploads the artifact and fails on wall regressions beyond a
tolerance vs the committed baseline (``benchmarks/BENCH_baseline.json``).

Walls are measured wall-clock (inherently machine-dependent); regression
checks therefore compare *ratios* against a baseline recorded on the same
class of runner, with generous tolerance.  Everything else in the artifact
(counts, speedup ratios, acceptance booleans) is seed-deterministic.
"""
from __future__ import annotations

import json
import platform
import sys

BENCH_SCHEMA = "repro.bench_sim/v1"

# suite name -> list of required keys in its result dict
_SUITE_KEYS = {
    "bench_sim_scale": ("cells", "phases"),
    "overhead_matching": ("steady_state", "km_scaling", "phases"),
    "kernel_bench": ("cells", "phases"),
    "obs_overhead": ("cells", "overhead", "tick_phases", "phases"),
    "durability_overhead": ("append", "snapshot", "recovery", "phases"),
}


def environment() -> dict:
    import numpy

    import jax
    return {
        "python": sys.version.split()[0],
        "numpy": numpy.__version__,
        "jax": jax.__version__,
        "platform": platform.machine(),
    }


def make_artifact(suites: dict, *, smoke: bool, seed: int = 0) -> dict:
    return {
        "schema": BENCH_SCHEMA,
        "smoke": bool(smoke),
        "seed": seed,
        "env": environment(),
        "suites": suites,
    }


def check_schema(doc: dict) -> list[str]:
    """Validate a BENCH_sim.json document; returns problems (empty = ok)."""
    problems = []
    if doc.get("schema") != BENCH_SCHEMA:
        problems.append(f"schema != {BENCH_SCHEMA!r}: {doc.get('schema')!r}")
    for k in ("smoke", "seed", "env", "suites"):
        if k not in doc:
            problems.append(f"missing key {k!r}")
    suites = doc.get("suites") or {}
    for name, keys in _SUITE_KEYS.items():
        if name not in suites:
            problems.append(f"missing suite {name!r}")
            continue
        for k in keys:
            if k not in suites[name]:
                problems.append(f"suite {name!r} missing {k!r}")
    ss = (suites.get("overhead_matching") or {}).get("steady_state") or {}
    for k in ("seed_round_s", "cold_round_s", "warm_round_s", "speedup",
              "warm_equals_cold"):
        if k not in ss:
            problems.append(f"steady_state missing {k!r}")
    return problems


def compare_walls(current: dict, baseline: dict,
                  max_ratio: float = 1.5) -> list[str]:
    """Wall-regression gate: every suite's headline walls must stay within
    ``max_ratio`` × the committed baseline.  Returns violations."""
    problems = []
    if bool(current.get("smoke")) != bool(baseline.get("smoke")):
        # full-mode walls vs a smoke baseline (or vice versa) would produce
        # meaningless ratios — refuse instead of misreporting
        return [f"mode mismatch: current smoke={current.get('smoke')} vs "
                f"baseline smoke={baseline.get('smoke')}"]
    cur_s, base_s = current.get("suites", {}), baseline.get("suites", {})
    for suite, base in base_s.items():
        cur = cur_s.get(suite)
        if cur is None:
            problems.append(f"suite {suite!r} missing from current run")
            continue
        for key, base_wall in (base.get("headline_walls") or {}).items():
            cur_wall = (cur.get("headline_walls") or {}).get(key)
            if cur_wall is None:
                problems.append(f"{suite}:{key} missing from current run")
            elif base_wall > 0 and cur_wall > base_wall * max_ratio:
                problems.append(
                    f"{suite}:{key} regressed: {cur_wall:.3f}s > "
                    f"{max_ratio}x baseline {base_wall:.3f}s")
    return problems


def main(argv=None) -> int:
    """``python -m benchmarks.bench_schema --check FILE [--baseline FILE]``"""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", required=True, metavar="BENCH_sim.json")
    ap.add_argument("--baseline", default=None,
                    metavar="BENCH_baseline.json")
    ap.add_argument("--max-ratio", type=float, default=1.5)
    args = ap.parse_args(argv)
    with open(args.check) as f:
        doc = json.load(f)
    problems = check_schema(doc)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        problems += compare_walls(doc, baseline, max_ratio=args.max_ratio)
    for p in problems:
        print(f"BENCH: {p}", file=sys.stderr)
    print("bench artifact " + ("FAIL" if problems else "OK"),
          file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
