"""Paper-scale simulator benchmark: vectorized-engine throughput across
cluster sizes and policies (MuxFlow deploys on > 20 000 GPUs — §7/§8).

Per (n_devices, policy) cell this reports wall time, simulated ticks/second,
and schedule-round latency (mean/max), plus headline sim metrics as a sanity
check.  Emits the suite's usual ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python benchmarks/bench_sim_scale.py                # full sweep
  PYTHONPATH=src python benchmarks/bench_sim_scale.py --smoke        # tiny CI config
  PYTHONPATH=src python benchmarks/bench_sim_scale.py \
      --devices 200,2000,20000 --policies muxflow,online-only \
      --trace A --horizon-h 12 --tick 30

Acceptance targets (ISSUE 1): a 20 000-device, 12-hour, 30 s-tick MuxFlow
run completes in < 5 minutes on CPU; a schedule round at 20k completes in
< 10 s.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.simulator import ClusterSim, SimConfig
from repro.policies import available, resolve

try:
    from .bench_lib import emit
except ImportError:  # running as a script: python benchmarks/bench_sim_scale.py
    from bench_lib import emit  # type: ignore


def _build_predictor(tiny: bool):
    from repro.core.predictor import build_speed_predictor
    if tiny:
        return build_speed_predictor(gpu_types=("T4", "A10"), n=150, epochs=5)
    return build_speed_predictor(gpu_types=("T4", "A10"), n=600, epochs=30)


def bench_cell(policy: str, n_devices: int, predictor, *, horizon_s: float,
               tick_s: float, trace: str, seed: int = 0,
               engine: str = "numpy") -> dict:
    cfg = SimConfig(policy=policy, n_devices=n_devices, horizon_s=horizon_s,
                    tick_s=tick_s, trace=trace, seed=seed, engine=engine)
    sim = ClusterSim(cfg,
                     predictor if resolve(policy).needs_predictor else None)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    n_ticks = int(horizon_s / tick_s)
    sl = sim.schedule_latencies or [0.0]
    return {
        "wall_s": wall,
        "ticks_per_s": n_ticks / max(wall, 1e-9),
        "sched_mean_s": float(np.mean(sl)),
        "sched_max_s": float(max(sl)),
        "res": res,
    }


def bench_scenario(name: str, *, n_devices: int, hours: float,
                   seed: int = 0) -> None:
    """Control-plane overhead cell: a full scenario (events, agents, faults,
    autoscaling, JSON report) vs the raw engine's ticks/s."""
    from repro.cluster import run_scenario
    t0 = time.perf_counter()
    rep = run_scenario(name, n_devices=n_devices, hours=hours, seed=seed)
    wall = time.perf_counter() - t0
    n_ticks = int(hours * 3600.0 / rep["scenario"]["tick_s"])
    emit(f"simscale_scenario_{name}_n{n_devices}", wall * 1e6,
         f"{n_ticks / max(wall, 1e-9):.1f}ticks/s;"
         f"events={rep['events']['n_events']};"
         f"done={rep['jobs']['completed']}/{rep['jobs']['n_jobs']};"
         f"faults={rep['faults']['injected'] if rep['faults'] else 0};"
         f"digest={rep['events']['digest'][:8]}")


def sweep(devices, policies, *, horizon_s, tick_s, trace, predictor) -> int:
    failures = 0
    for n in devices:
        for pol in policies:
            c = bench_cell(pol, n, predictor, horizon_s=horizon_s,
                           tick_s=tick_s, trace=trace)
            r = c["res"]
            emit(f"simscale_n{n}_{pol}", c["wall_s"] * 1e6,
                 f"{c['ticks_per_s']:.1f}ticks/s;sched_mean={c['sched_mean_s']*1e3:.0f}ms;"
                 f"sched_max={c['sched_max_s']*1e3:.0f}ms;done={r.n_finished}/{r.n_jobs};"
                 f"slow={r.avg_slowdown:.3f};oversold={r.oversold_gpu:.3f}")
            if pol == "muxflow" and n >= 20_000:
                ok_wall = c["wall_s"] < 300.0
                ok_round = c["sched_max_s"] < 10.0
                emit(f"simscale_accept_n{n}", 0.0,
                     f"run<5min:{'PASS' if ok_wall else 'FAIL'};"
                     f"round<10s:{'PASS' if ok_round else 'FAIL'}")
                failures += (not ok_wall) + (not ok_round)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", default="200,2000,20000")
    ap.add_argument("--policies", default="all",
                    help="'all' or comma-separated subset of "
                         + ",".join(available()))
    ap.add_argument("--trace", default="A")
    ap.add_argument("--horizon-h", type=float, default=12.0)
    ap.add_argument("--tick", type=float, default=30.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 64 devices, 30 min, 2 policies")
    args = ap.parse_args(argv)
    if args.smoke:
        devices = [64]
        policies = ["muxflow", "online-only"]
        horizon_s, tick_s = 1800.0, args.tick
    else:
        devices = [int(d) for d in args.devices.split(",")]
        policies = (list(available()) if args.policies == "all"
                    else args.policies.split(","))
        horizon_s, tick_s = args.horizon_h * 3600.0, args.tick
    for p in policies:
        resolve(p)          # unknown names raise with the available list
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    predictor = _build_predictor(tiny=args.smoke)
    emit("simscale_predictor_train", (time.perf_counter() - t0) * 1e6, "")
    failures = sweep(devices, policies, horizon_s=horizon_s, tick_s=tick_s,
                     trace=args.trace, predictor=predictor)
    if args.smoke:
        bench_scenario("smoke", n_devices=64, hours=0.5)
    else:
        bench_scenario("diurnal-mixed", n_devices=max(devices), hours=2.0)
    return 1 if failures else 0


def run() -> None:
    """Moderate sweep for ``python -m benchmarks.run simscale``."""
    predictor = _build_predictor(tiny=True)
    sweep([200, 2000], ["muxflow", "time-sharing", "online-only"],
          horizon_s=2 * 3600.0, tick_s=30.0, trace="A", predictor=predictor)


def run_json(smoke: bool = False) -> dict:
    """Structured engine-comparison cells for BENCH_sim.json.

    Every cell runs both tick engines at the same seed and records, besides
    the walls, whether the engines' SimResults were byte-identical — the
    perf trajectory doubles as a cross-engine parity canary.
    """
    import dataclasses as _dc
    import json as _json
    t0 = time.perf_counter()
    predictor = _build_predictor(tiny=smoke)
    t_pred = time.perf_counter() - t0
    shapes = ([(200, 1800.0)] if smoke
              else [(2000, 4 * 3600.0), (20000, 12 * 3600.0)])
    cells = []
    for n, horizon_s in shapes:
        for pol in ("muxflow", "time-sharing"):
            reprs = {}
            for engine in ("numpy", "xla"):
                if smoke:
                    # tiny CI shapes: exclude one-time jit/kernel compiles
                    # from the recorded wall (full shapes amortize them)
                    bench_cell(pol, n, predictor, horizon_s=horizon_s,
                               tick_s=30.0, trace="B", engine=engine)
                c = bench_cell(pol, n, predictor, horizon_s=horizon_s,
                               tick_s=30.0, trace="B", engine=engine)
                reprs[engine] = _json.dumps(_dc.asdict(c.pop("res")),
                                            sort_keys=True)
                cells.append({"policy": pol, "n_devices": n,
                              "horizon_s": horizon_s, "engine": engine,
                              **c})
            cells[-1]["engines_byte_identical"] = (
                reprs["numpy"] == reprs["xla"])
    headline = {}
    for c in cells:
        if c["policy"] == "muxflow" and c["n_devices"] == max(
                s[0] for s in shapes):
            headline[f"muxflow_n{c['n_devices']}_{c['engine']}"] = \
                c["wall_s"]
    return {
        "cells": cells,
        "phases": {"predictor_train_s": t_pred},
        "headline_walls": headline,
    }


if __name__ == "__main__":
    sys.exit(main())
