"""Shared helpers for the benchmark suite: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived = the
figure's headline quantity, labeled in the name).
"""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median-ish wall time per call in µs."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
