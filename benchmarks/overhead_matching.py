"""§7.4 system overhead — scheduler scalability.

Three phases:

* batched prediction + KM runtime vs problem size (the paper's numbers:
  predictions < 1 ms each, KM minutes for thousands of workloads, hidden
  inside the scheduling interval);
* **steady state**: repeated scheduling rounds over a slowly drifting
  fleet (diurnal QPS drift, small free-set churn — what rounds look like
  between the backlog build-up and drain phases).  Measures the full
  per-round matching overhead (weight grid + solve) three ways:

    - ``seed_round_s``  — a faithful emulation of the pre-incremental
      round: per-slot Python profile objects, a per-row Python dict memo
      over every (device × model) prediction, and a cold partitioned
      match.  This is what every round cost before the fused-engine PR;
    - ``cold_round_s``  — the shipped array path with a *fresh* predictor
      memo and a cold matcher each round (what a one-off round costs now);
    - ``warm_round_s``  — the shipped steady-state path: persistent
      :class:`~repro.core.predictor.CachedSpeedPredictor` (vectorized
      quantized-row memo) + persistent
      :class:`~repro.core.matching.IncrementalMatcher`.

  The warm path must stay ≥ 5× cheaper per round than the seed path, and
  its assignments are asserted identical to a cold solve of the same
  inputs (the incremental matcher is exact by construction);
* the structured ``run_json`` form of both for ``BENCH_sim.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.interference import OFFLINE_MODEL_PROFILES, online_profile_arrays
from repro.core.matching import IncrementalMatcher, km_match, sharded_match_compact
from repro.core.predictor import N_FEATURES, CachedSpeedPredictor
from repro.core.scheduler import OfflineJob, SchedulerConfig, build_weight_grid_arrays
from repro.core.traces import SERVICES

from .bench_lib import emit
from .predictor_cache import get_predictor


def _steady_state_rounds(n_devices: int, backlog: int, rounds: int,
                         seed: int = 0):
    """Generate scheduler-shaped rounds: per-device services/types, diurnal
    QPS drift between rounds, and a small free-set churn (jobs finishing /
    being placed)."""
    rng = np.random.default_rng(seed)
    service_idx = np.array([i % len(SERVICES) for i in range(n_devices)],
                           np.int64)
    gpu_types = np.array(["T4", "T4", "T4", "A10"], dtype="<U4")[
        np.arange(n_devices) % 4]
    qps0 = rng.uniform(30.0, 160.0, n_devices)
    free_mask = rng.random(n_devices) < 0.85
    models = list(OFFLINE_MODEL_PROFILES)
    job_models = rng.integers(0, len(models), backlog)
    out = []
    for r in range(rounds):
        # diurnal drift: ~0.3 % per 15-min round, plus minute noise
        qps = qps0 * (1.0 + 0.003 * r) + rng.normal(0.0, 0.2, n_devices)
        # churn: ~1 % of devices flip free<->busy per round
        flips = rng.random(n_devices) < 0.01
        free_mask = free_mask ^ flips
        on = online_profile_arrays(service_idx, np.clip(qps, 20.0, 240.0),
                                   SERVICES)
        free = np.flatnonzero(free_mask)
        jobs = [OfflineJob(int(1000 * r + j),
                           OFFLINE_MODEL_PROFILES[models[m]], 3600.0)
                for j, m in enumerate(job_models)]
        out.append((free, gpu_types, service_idx, on, jobs))
    return out


def _run_round(rnd, predictor, cfg, matcher):
    from repro.core.dynamic_sm import dynamic_sm_array
    free, gpu_types, service_idx, on, jobs = rnd
    shares = dynamic_sm_array(on["sm_activity"][free])
    on_feats = np.stack(
        [on["gpu_util"][free], on["sm_activity"][free],
         on["sm_occupancy"][free], on["exec_time_ms"][free] / 1000.0],
        axis=1).astype(np.float32)
    values, col_group = build_weight_grid_arrays(
        gpu_types[free], on_feats, shares, jobs, predictor, cfg)
    if matcher is not None:
        pairs = matcher.match(values, col_group, row_ids=free)
    else:
        pairs = sharded_match_compact(values, col_group,
                                      shard_size=cfg.shard_size,
                                      row_slack=cfg.row_slack)
    return pairs


class _SeedEraRowMemo:
    """The pre-PR predictor memo, faithfully: one Python dict lookup (and
    ``tobytes`` key) per (device × model) row, misses batched."""

    def __init__(self, inner, quantum=0.02):
        self.inner = inner
        self.quantum = quantum
        self._cache = {}

    @property
    def params_by_type(self):
        return self.inner.params_by_type

    def predict(self, gpu_type, feats):
        rows = np.asarray(feats, np.float32).reshape(-1, feats.shape[-1])
        rows = (np.round(rows / self.quantum)
                * self.quantum).astype(np.float32)
        out = np.empty(rows.shape[0], np.float32)
        miss = []
        keys = [(gpu_type, rows[i].tobytes()) for i in range(rows.shape[0])]
        for i, key in enumerate(keys):
            val = self._cache.get(key)
            if val is None:
                miss.append(i)
            else:
                out[i] = val
        if miss:
            import jax.numpy as jnp

            from repro.core.predictor import mlp_apply
            mi = np.asarray(miss)
            # the seed's SpeedPredictor.predict was an *eager* (op-by-op)
            # mlp_apply, not a jitted one — reproduce that cost honestly
            pred = np.asarray(mlp_apply(self.inner.params_by_type[gpu_type],
                                        jnp.asarray(rows[mi])), np.float32)
            out[mi] = pred
            for i, p in zip(miss, pred):
                self._cache[keys[i]] = float(p)
        return out


def _seed_era_round(rnd, memo, cfg):
    """Pre-PR round shape: per-slot objects through the slot-list API and a
    per-row dict memo, cold compact matching."""
    from repro.core.interference import WorkloadProfile
    from repro.core.scheduler import OnlineSlot, schedule
    free, gpu_types, service_idx, on, jobs = rnd
    services = SERVICES
    slots = [
        OnlineSlot(int(i), str(gpu_types[i]), WorkloadProfile(
            name=services[service_idx[i]],
            gpu_util=float(on["gpu_util"][i]),
            sm_activity=float(on["sm_activity"][i]),
            sm_occupancy=float(on["sm_occupancy"][i]),
            mem_bw=float(on["mem_bw"][i]),
            exec_time_ms=float(on["exec_time_ms"][i]),
            mem_bytes_frac=float(on["mem_bytes_frac"][i])))
        for i in free]
    return schedule(slots, jobs, memo, cfg)


def steady_state(n_devices: int = 16000, backlog: int = 800,
                 rounds: int = 10, seed: int = 0) -> dict:
    # backlog sized like the simulator's own steady state (a few hundred
    # pending jobs against a mostly-free fleet — measured on diurnal-mixed
    # at 20 000 devices), not a synthetic pile-up
    """Cold-vs-warm per-round matching overhead in the steady-state phase."""
    inner = get_predictor()
    cfg = SchedulerConfig()
    rnds = _steady_state_rounds(n_devices, backlog, rounds, seed=seed)
    # warmup one round (jit/trace costs must not pollute any side)
    _run_round(rnds[0], CachedSpeedPredictor(inner, quantum=0.02), cfg, None)

    seed_t, cold_t, warm_t = [], [], []
    seed_memo = _SeedEraRowMemo(inner)
    warm_pred = CachedSpeedPredictor(inner, quantum=0.02)
    warm_matcher = IncrementalMatcher(shard_size=cfg.shard_size,
                                      row_slack=cfg.row_slack)
    warm_pairs_all, cold_pairs_all = [], []
    for rnd in rnds:
        t0 = time.perf_counter()
        _seed_era_round(rnd, seed_memo, cfg)
        seed_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _run_round(rnd, CachedSpeedPredictor(inner, quantum=0.02), cfg, None)
        cold_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        warm_pairs_all.append(_run_round(rnd, warm_pred, cfg, warm_matcher))
        warm_t.append(time.perf_counter() - t0)
        # exactness: warm == a cold solve by a fresh incremental matcher
        cold_pairs_all.append(_run_round(
            rnd, CachedSpeedPredictor(inner, quantum=0.02), cfg,
            IncrementalMatcher(shard_size=cfg.shard_size,
                               row_slack=cfg.row_slack)))

    def trimmed(xs):
        return float(np.mean(sorted(xs)[:-1])) if rounds > 1 else xs[0]

    seed, cold, warm = trimmed(seed_t), trimmed(cold_t), trimmed(warm_t)
    return {
        "n_devices": n_devices, "backlog": backlog, "rounds": rounds,
        "seed_round_s": seed, "cold_round_s": cold, "warm_round_s": warm,
        "speedup": seed / max(warm, 1e-9),
        "cold_speedup": cold / max(warm, 1e-9),
        "warm_equals_cold": warm_pairs_all == cold_pairs_all,
        "predictor_cache": warm_pred.stats(),
        "matcher": warm_matcher.stats(),
    }


def km_scaling() -> list[dict]:
    rng = np.random.default_rng(0)
    out = []
    for n in (50, 200, 600):
        w = rng.uniform(0, 1, (n, n))
        t0 = time.perf_counter()
        pairs = km_match(w)
        dt = time.perf_counter() - t0
        out.append({"n": n, "wall_s": dt, "pairs": len(pairs)})
    return out


def prediction_batches(pred) -> list[dict]:
    out = []
    for n in (1000, 10_000):
        feats = np.random.default_rng(0).uniform(
            0, 1, (n, N_FEATURES)).astype(np.float32)
        t0 = time.perf_counter()
        pred.predict("T4", feats)
        dt = time.perf_counter() - t0
        out.append({"n": n, "wall_s": dt, "us_per_pair": dt / n * 1e6})
    return out


def run_json(smoke: bool = False) -> dict:
    """Structured results for BENCH_sim.json."""
    t0 = time.perf_counter()
    pred = get_predictor()
    t_pred = time.perf_counter() - t0
    t0 = time.perf_counter()
    ss = (steady_state(n_devices=8000, backlog=400, rounds=8) if smoke
          else steady_state())
    t_ss = time.perf_counter() - t0
    t0 = time.perf_counter()
    km = km_scaling()
    batches = prediction_batches(pred)
    t_micro = time.perf_counter() - t0
    return {
        "steady_state": ss,
        "km_scaling": km,
        "prediction_batches": batches,
        "phases": {"predictor_train_s": t_pred, "steady_state_s": t_ss,
                   "micro_s": t_micro},
        "headline_walls": {"steady_state_warm_round": ss["warm_round_s"]},
    }


def run() -> None:
    pred = get_predictor()
    for b in prediction_batches(pred):
        emit(f"overhead_predict_batch_{b['n']}", b["wall_s"] * 1e6,
             f"{b['us_per_pair']:.2f}us/pair (paper <1ms/pair)")
    km = km_scaling()
    for c in km:
        emit(f"overhead_km_n{c['n']}", c["wall_s"] * 1e6,
             f"{c['pairs']} pairs in {c['wall_s']*1e3:.1f}ms")
    # extrapolate O(n^3) to the paper's "thousands of workloads"
    t600 = [c for c in km if c["n"] == 600][0]["wall_s"]
    t4000 = t600 * (4000 / 600) ** 3
    emit("overhead_km_extrapolated_n4000", t4000 * 1e6,
         f"{t4000/60:.1f}min (paper: several minutes; hidden in interval)")
    ss = steady_state(n_devices=8000, backlog=400, rounds=8)
    emit("overhead_round_steady_seed", ss["seed_round_s"] * 1e6,
         f"{ss['seed_round_s']*1e3:.1f}ms/round (pre-PR slot+dict path)")
    emit("overhead_round_steady_cold", ss["cold_round_s"] * 1e6,
         f"{ss['cold_round_s']*1e3:.1f}ms/round (fresh memo + cold shards)")
    emit("overhead_round_steady_warm", ss["warm_round_s"] * 1e6,
         f"{ss['warm_round_s']*1e3:.1f}ms/round;speedup={ss['speedup']:.1f}x;"
         f"exact={'PASS' if ss['warm_equals_cold'] else 'FAIL'}")
