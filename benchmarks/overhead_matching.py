"""§7.4 system overhead — scheduler scalability: batched prediction + KM
runtime vs problem size (paper: predictions < 1 ms each / several seconds
batched; KM takes minutes for thousands of workloads and hides inside the
scheduling interval).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.matching import km_match
from .bench_lib import emit
from .predictor_cache import get_predictor
from repro.core.predictor import N_FEATURES


def run() -> None:
    pred = get_predictor()
    # batched prediction throughput
    for n in (1000, 10_000):
        feats = np.random.default_rng(0).uniform(0, 1, (n, N_FEATURES)).astype(np.float32)
        t0 = time.perf_counter()
        pred.predict("T4", feats)
        dt = time.perf_counter() - t0
        emit(f"overhead_predict_batch_{n}", dt * 1e6,
             f"{dt/n*1e6:.2f}us/pair (paper <1ms/pair)")
    # KM scaling
    rng = np.random.default_rng(0)
    for n in (50, 200, 600):
        w = rng.uniform(0, 1, (n, n))
        t0 = time.perf_counter()
        pairs = km_match(w)
        dt = time.perf_counter() - t0
        emit(f"overhead_km_n{n}", dt * 1e6,
             f"{len(pairs)} pairs in {dt*1e3:.1f}ms")
    # extrapolate O(n^3) to the paper's "thousands of workloads"
    t0 = time.perf_counter()
    km_match(rng.uniform(0, 1, (600, 600)))
    t600 = time.perf_counter() - t0
    t4000 = t600 * (4000 / 600) ** 3
    emit("overhead_km_extrapolated_n4000", t4000 * 1e6,
         f"{t4000/60:.1f}min (paper: several minutes; hidden in interval)")
