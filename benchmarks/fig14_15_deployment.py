"""Figures 14–15 — deployment-style results: long-horizon fleet averages of
online latency and GPU resource utilization, MuxFlow vs Online-only.

Paper: avg and p99 latency increase < 10 ms; GPU util 26 %→76 %,
SM activity 16 %→33 %, memory 42 %→48 %; daily device error rate 0.9 % vs
0.7 % baseline.  (Deployment ran without dynamic-SM + matching — we model
that with the MuxFlow-S-M variant, plus full MuxFlow for comparison.)
"""
from __future__ import annotations

import time

# rides the repro.cluster control plane (neutral passthrough: same
# engine + RNG stream as repro.core.simulator.run_policy)
from repro.cluster.control import run_policy_scenario as run_policy
from .bench_lib import emit
from .predictor_cache import get_predictor

CFG = dict(n_devices=150, horizon_s=24 * 3600.0, tick_s=120.0, trace="D", seed=4)


def run() -> None:
    pred = get_predictor()
    t0 = time.perf_counter()
    base = run_policy("online-only", None, **CFG)
    depl = run_policy("muxflow-s-m", pred, **CFG)    # deployment config
    full = run_policy("muxflow", pred, **CFG)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig14_latency_increase_ms", us,
         f"avg +{depl.avg_latency_ms-base.avg_latency_ms:.1f}ms,"
         f"p99 +{depl.p99_latency_ms-base.p99_latency_ms:.1f}ms (paper <10ms)")
    emit("fig15_gpu_util", 0.0,
         f"{base.gpu_util*100:.0f}%->{depl.gpu_util*100:.0f}% (paper 26%->76%)")
    emit("fig15_sm_activity", 0.0,
         f"{base.sm_activity*100:.0f}%->{depl.sm_activity*100:.0f}% (paper 16%->33%)")
    emit("fig15_gpu_memory", 0.0,
         f"{base.mem_used*100:.0f}%->{depl.mem_used*100:.0f}% (paper 42%->48%)")
    emit("fig15_full_muxflow_gpu_util", 0.0,
         f"{full.gpu_util*100:.0f}% (dynamic SM + matching enabled)")
