"""Kernel/step microbenchmarks on the CPU reference path (wall times are
CPU-only context; the TPU story lives in the dry-run roofline, §EXPERIMENTS).
Derived column reports achieved GFLOP/s for the compute steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_cache, init_params, make_decode_step, make_train_step
from repro.models import layers as L
from repro.optim.optimizer import AdamW, AdamWConfig
from .bench_lib import emit, timeit


def run_json(smoke: bool = False) -> dict:
    """Structured kernel cells for BENCH_sim.json: the attention pair plus
    one real train/decode smoke arch (all archs in full mode)."""
    import time as _time
    t0 = _time.perf_counter()
    key = jax.random.PRNGKey(0)
    B, S, H, Hk, d = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, d),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, d),
                          jnp.float32)
    flops = 4.0 * B * H * S * S * d
    att_m = jax.jit(lambda q, k, v: L.attention(q, k, v, causal=True))
    att_c = jax.jit(lambda q, k, v: L.attention_chunked(
        q, k, v, causal=True, chunk_q=512, chunk_k=512))
    cells = []
    for name, fn in (("attn_materialized_2k", att_m),
                     ("attn_chunked_2k", att_c)):
        us = timeit(lambda: jax.block_until_ready(fn(q, k, v)), iters=3)
        cells.append({"name": name, "us_per_call": us,
                      "gflops": flops / us / 1e3})
    t_attn = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    archs = (("xlstm-350m",) if smoke
             else ("h2o-danube-1.8b", "deepseek-v2-lite-16b",
                   "jamba-1.5-large-398b", "xlstm-350m"))
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        params = init_params(key, cfg)
        opt = AdamW(AdamWConfig(total_steps=100))
        ts = jax.jit(make_train_step(cfg, opt))
        batch = {"tokens": jax.random.randint(key, (4, 64), 0,
                                              cfg.vocab_size)}
        if cfg.frontend == "audio":
            batch["src_embeds"] = jax.random.normal(
                key, (4, 64, cfg.d_model), cfg.dtype)
        if cfg.frontend == "patch":
            batch["patch_embeds"] = jax.random.normal(
                key, (4, cfg.num_patches, cfg.d_model), cfg.dtype)
            batch["tokens"] = batch["tokens"][:, :64 - cfg.num_patches]
        st = opt.init(params)
        us = timeit(lambda: jax.block_until_ready(
            ts(params, st, batch)[2]["loss"]), iters=3)
        cells.append({"name": f"train_step_smoke_{arch}", "us_per_call": us,
                      "tok_per_s": 4 * 64 / (us / 1e6)})
    t_steps = _time.perf_counter() - t0
    return {
        "cells": cells,
        "phases": {"attention_s": t_attn, "train_steps_s": t_steps},
        "headline_walls": {c["name"]: c["us_per_call"] / 1e6
                           for c in cells if "attn" in c["name"]},
    }


def run() -> None:
    key = jax.random.PRNGKey(0)
    # chunked attention vs materialized (the jnp flash analogue)
    B, S, H, Hk, d = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, d), jnp.float32)
    flops = 4.0 * B * H * S * S * d          # qk + pv
    att_m = jax.jit(lambda q, k, v: L.attention(q, k, v, causal=True))
    att_c = jax.jit(lambda q, k, v: L.attention_chunked(q, k, v, causal=True,
                                                        chunk_q=512, chunk_k=512))
    for name, fn in (("attn_materialized_2k", att_m), ("attn_chunked_2k", att_c)):
        us = timeit(lambda: jax.block_until_ready(fn(q, k, v)), iters=3)
        emit(name, us, f"{flops/us/1e3:.1f}GFLOP/s")

    # per-arch smoke step times (train + decode)
    for arch in ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "jamba-1.5-large-398b",
                 "xlstm-350m"):
        cfg = get_config(arch, smoke=True)
        params = init_params(key, cfg)
        opt = AdamW(AdamWConfig(total_steps=100))
        ts = jax.jit(make_train_step(cfg, opt))
        batch = {"tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}
        if cfg.frontend == "audio":
            batch["src_embeds"] = jax.random.normal(key, (4, 64, cfg.d_model), cfg.dtype)
        if cfg.frontend == "patch":
            batch["patch_embeds"] = jax.random.normal(key, (4, cfg.num_patches, cfg.d_model), cfg.dtype)
            batch["tokens"] = batch["tokens"][:, :64 - cfg.num_patches]
        st = opt.init(params)
        us = timeit(lambda: jax.block_until_ready(
            ts(params, st, batch)[2]["loss"]), iters=3)
        tokens = 4 * 64
        emit(f"train_step_smoke_{arch}", us, f"{tokens/(us/1e6):.0f}tok/s")
        dec = jax.jit(make_decode_step(cfg))
        cache = init_cache(cfg, 4, 64, src_len=64 if cfg.enc_layers else 0)
        us = timeit(lambda: jax.block_until_ready(
            dec(params, cache, batch["tokens"][:, :1], 32)[0]), iters=5)
        emit(f"decode_step_smoke_{arch}", us, f"{4/(us/1e6):.0f}tok/s")
