"""Figure 13 — mechanism ablations over traces A–D: MuxFlow vs MuxFlow-S
(no dynamic SM), MuxFlow-M (no matching), MuxFlow-S-M (neither).

Paper: both mechanisms improve JCT and oversold; the combination is best.
"""
from __future__ import annotations

import time

# rides the repro.cluster control plane (neutral passthrough: same
# engine + RNG stream as repro.core.simulator.run_policy)
from repro.cluster.control import run_policy_scenario as run_policy
from repro.policies import resolve

from .bench_lib import emit
from .predictor_cache import get_predictor

BASE = dict(n_devices=80, horizon_s=6 * 3600.0, tick_s=60.0, seed=2)


def run() -> None:
    pred = get_predictor()
    for trace in ("A", "B", "C", "D"):
        res = {}
        for pol in ("muxflow", "muxflow-s", "muxflow-m", "muxflow-s-m"):
            t0 = time.perf_counter()
            res[pol] = run_policy(pol,
                                  pred if resolve(pol).needs_predictor
                                  else None,
                                  trace=trace, **BASE)
            emit(f"fig13_{trace}_{pol}", (time.perf_counter() - t0) * 1e6,
                 f"jct={res[pol].avg_jct_s:.0f}s;oversold={res[pol].oversold_gpu:.3f};"
                 f"slow={res[pol].avg_slowdown:.3f}")
        full = res["muxflow"]
        abl = res["muxflow-s-m"]
        emit(f"fig13_{trace}_full_vs_sm_ablation", 0.0,
             f"jct {abl.avg_jct_s/max(full.avg_jct_s,1e-9):.2f}x;"
             f"oversold {full.oversold_gpu/max(abl.oversold_gpu,1e-9):.2f}x")
